"""Streaming SharesSkew quickstart: join a drifting Zipf stream.

A 2-way join R(A,B) ⋈ S(B,C) ingested as micro-batches whose skew profile
shifts mid-run: the Zipf-heavy B values move to a different part of the
domain.  Watch the telemetry — the sketches notice the new heavy hitters,
the drift monitor declares the running plan overloaded, and a replan event
fires (carried reducer state is migrated to the new layout).  The final
cumulative (count, checksum) is verified against the batch oracle on the
full concatenated input.

The loop runs under ``train.elastic.PreemptionGuard`` (DESIGN.md §8): a
SIGTERM mid-stream is caught at the next batch boundary, the engine writes
a checkpoint with ``save_checkpoint``, and the process exits cleanly —
rerunning with the same ``--ckpt-dir`` restores the engine mid-stream and
finishes the remaining batches with bit-identical fingerprints.

``--kill-reducer H`` demos in-flight reducer-loss recovery (DESIGN.md §5)
instead: reducers multiplex over 8 simulated hosts, host H is killed
right after the drift, and the engine recovers at that batch boundary by
lineage replay from the retained window — no checkpoint involved — then
verifies the window fingerprint bit-for-bit.

``--queries N`` demos the multi-tenant engine (DESIGN.md §9): N copies of
the query run behind ONE shared sketch ingest per relation batch.  A
poison-pill batch is injected into tenant q1 mid-run — the circuit
breaker quarantines it while every other tenant stays bit-identical to a
single-tenant run (verified against the oracle at the end).

``--trace out.json`` (DESIGN.md §10) records the whole run as nested
spans — ingest, sketch update, route, delta join, drift checks, replan
(solve/migrate split out), recovery replay — and writes Chrome
trace-event JSON loadable in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``.  Combine with ``--kill-reducer`` to see the drift
replan AND the recovery boundary on one timeline.

Run:  PYTHONPATH=src python examples/streaming_join.py
      PYTHONPATH=src python examples/streaming_join.py --ckpt-dir /tmp/sj
      (kill -TERM the process mid-run, then rerun the same command)
      PYTHONPATH=src python examples/streaming_join.py --kill-reducer 2
      PYTHONPATH=src python examples/streaming_join.py --queries 3
      PYTHONPATH=src python examples/streaming_join.py \
          --kill-reducer 2 --trace trace.json
"""
import argparse
import sys

import numpy as np

from repro.core import two_way
from repro.mapreduce import oracle_join
from repro.stream import (
    MultiQueryEngine,
    ObsPolicy,
    RecoveryPolicy,
    RetentionPolicy,
    StreamConfig,
    StreamingJoinEngine,
    TenancyPolicy,
    TenantSpec,
)
from repro.testing.faults import FaultInjector, FaultSpec
from repro.train import PreemptionGuard
from repro.train.checkpoint import latest_step

N_BATCHES = 8


def zipf_batch(rng, shift, n_r=1200, n_s=300, domain=3000, a=1.6):
    """One micro-batch; heavy B values cluster at ``shift`` (mod domain)."""
    b_r = ((rng.zipf(a, n_r) - 1) + shift) % domain
    b_s = ((rng.zipf(a, n_s) - 1) + shift) % domain
    r = np.stack([rng.integers(0, domain, n_r), b_r], 1).astype(np.int64)
    s = np.stack([b_s, rng.integers(0, domain, n_s)], 1).astype(np.int64)
    return {"R": r, "S": s}


def multi_query_demo(n_queries: int, trace: str | None = None) -> int:
    """N tenants, one shared sketch ingest, poison-pill containment."""
    query = two_way()
    config = StreamConfig(q=120, decay=0.5, load_factor=2.0)
    tenants = [
        TenantSpec(f"q{i}", query, config, weight=1.0 + (i == 0))
        for i in range(n_queries)
    ]
    policy = TenancyPolicy(
        obs=ObsPolicy(trace=True, metrics=True) if trace else ObsPolicy()
    )
    mq = MultiQueryEngine(tenants, policy, log_fn=print)
    inj = FaultInjector(
        [FaultSpec(kind="poison_rows", target="tenant", tenant="q1",
                   batch=4, poison="nan")]
    )
    mq.arm_faults(inj)
    print(f"streaming {query} for {n_queries} tenants; "
          f"poison-pill hits q1 at batch 4\n")

    rngs = [np.random.default_rng(0)]
    for _ in range(N_BATCHES):
        rngs.append(np.random.default_rng(rngs[-1].integers(2**63)))
    history: list[dict] = []
    for i in range(N_BATCHES):
        shift = 0 if i < 4 else 1300
        batch = zipf_batch(rngs[i], shift)
        history.append(batch)
        mq.ingest(batch)
        states = {nm: st.state for nm, st in mq.status().items()}
        if states.get("q1") != "RUNNING":
            print(f"  batch {i}: q1 is {states['q1']} "
                  f"(others: {sorted(set(states[n] for n in states if n != 'q1'))})")

    full = {
        nm: np.concatenate([b[nm] for b in history]) for nm in history[0]
    }
    count, checksum, _, _ = oracle_join(query, full)
    # q1 took the poison pill: it was quarantined, reopened, and skipped
    # the quarantine window — the isolation contract is about everyone ELSE
    clean = [nm for nm in mq.status() if nm != "q1"]
    for nm in clean:
        eng = mq.engine(nm)
        assert (eng.total_count, eng.total_checksum) == (count, checksum), nm
        assert eng.sketch_ingest_calls == 0, nm  # never computed privately
    q1 = mq.engine("q1")
    assert q1.total_count < count  # it really did miss batches
    inj.assert_all_resolved()
    rep = inj.report()
    print(f"\ntenants: {dict(sorted((nm, st.state) for nm, st in mq.status().items()))}")
    print(f"shared sketch passes: {mq.shared_sketch_passes} "
          f"(vs {mq.shared_sketch_passes * n_queries} for {n_queries} "
          f"separate engines); contained faults: {rep.contained}")
    print(f"verified: every unaffected tenant bit-identical to the oracle "
          f"({count} results, checksum {checksum:#010x}); q1 skipped its "
          f"quarantine window ({q1.total_count} results)")
    if trace:
        mq.obs.tracer.dump(trace)
        print(f"wrote {len(mq.obs.tracer.to_chrome()['traceEvents'])} trace "
              f"events to {trace} (load in https://ui.perfetto.dev)")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--ckpt-dir",
        default=None,
        help="checkpoint directory; enables SIGTERM-safe resume",
    )
    parser.add_argument(
        "--kill-reducer",
        type=int,
        default=None,
        metavar="HOST",
        help="kill this reducer host (0-7) right after the drift and "
        "recover in-flight by lineage replay (DESIGN.md §5)",
    )
    parser.add_argument(
        "--trace",
        default=None,
        metavar="OUT_JSON",
        help="enable the observability layer and write the run as "
        "Chrome/Perfetto trace-event JSON (DESIGN.md \u00a710)",
    )
    parser.add_argument(
        "--queries",
        type=int,
        default=None,
        metavar="N",
        help="run N tenant queries behind one shared sketch ingest and "
        "demo poison-pill containment (DESIGN.md §9)",
    )
    args = parser.parse_args(argv)

    if args.queries is not None:
        if args.queries < 2:
            parser.error("--queries needs N >= 2")
        return multi_query_demo(args.queries, trace=args.trace)

    query = two_way()
    obs = (
        ObsPolicy(trace=True, metrics=True, skewscope=True)
        if args.trace
        else ObsPolicy()
    )
    if args.kill_reducer is not None:
        # the recovery demo needs the host model + a retained window to
        # replay lost reducer state from
        config = StreamConfig(
            q=120, decay=0.5, load_factor=2.0,
            retention=RetentionPolicy(window_batches=4),
            recovery=RecoveryPolicy(n_hosts=8),
            obs=obs,
        )
    else:
        config = StreamConfig(q=120, decay=0.5, load_factor=2.0, obs=obs)

    start_batch = 0
    if args.ckpt_dir is not None and latest_step(args.ckpt_dir) is not None:
        engine = StreamingJoinEngine.restore(
            args.ckpt_dir, query, config, log_fn=print
        )
        start_batch = len(engine.reports)
        print(f"resumed from checkpoint at batch {start_batch}\n")
    else:
        engine = StreamingJoinEngine(query, config, log_fn=print)
        print(f"streaming {query} with a skew shift after batch 3\n")

    # the batch stream is a pure function of the batch index, so a resumed
    # run regenerates exactly the batches the interrupted run never ingested
    rngs = [np.random.default_rng(0)]
    for _ in range(N_BATCHES):
        rngs.append(np.random.default_rng(rngs[-1].integers(2**63)))

    with PreemptionGuard() as guard:
        for i in range(start_batch, N_BATCHES):
            shift = 0 if i < 4 else 1300  # the drift: heavy values move
            report = engine.ingest(zipf_batch(rngs[i], shift))
            if report.replanned and report.batch > 0:
                print(
                    f"  >>> REPLAN (epoch {report.plan_epoch}): "
                    f"{report.drift_reason}; "
                    f"migrated {report.migrated_tuples} emissions"
                )
            if args.kill_reducer is not None and i == 5:
                print(f"  >>> KILLING host {args.kill_reducer}")
                rec = engine.fail_hosts([args.kill_reducer])
                if rec is not None:
                    print(
                        f"  >>> RECOVERED ({rec.mode}): "
                        f"{rec.lost_reducers} reducer(s) lost, replayed "
                        f"{rec.replayed_tuples}/{rec.lost_share_tuples} "
                        f"lineage tuples from {rec.batches_replayed} "
                        f"retained batches, "
                        f"survivors {rec.survivors}/8, "
                        f"verified={rec.verified}"
                    )
            if guard.should_stop:
                if args.ckpt_dir is None:
                    print("\npreempted (no --ckpt-dir): stopping cleanly")
                    return 1
                path = engine.save_checkpoint(args.ckpt_dir)
                print(
                    f"\npreempted at batch {report.batch}: "
                    f"checkpointed to {path}; rerun to resume"
                )
                return 0

    print(f"\nreplans: {engine.replan_count}, "
          f"cumulative comm: {engine.cumulative_comm} tuples, "
          f"migrated: {engine.total_migrated}")

    count, checksum, _, _ = oracle_join(query, engine.history_data())
    if args.kill_reducer is not None:
        # retention is on in the recovery demo: the exactness contract is
        # the retained-window fingerprint (DESIGN.md §8)
        assert (engine.window_count, engine.window_checksum) == (
            count, checksum,
        )
        print(f"verified: post-recovery window count/checksum == oracle "
              f"on the retained window ({count} results, "
              f"checksum {checksum:#010x})")
    else:
        assert (engine.total_count, engine.total_checksum) == (count, checksum)
        print(f"verified: cumulative count/checksum == batch oracle "
              f"({count} results, checksum {checksum:#010x})")
    if args.trace:
        engine.obs.tracer.dump(args.trace)
        skew = engine.skew_report()
        print(f"wrote {len(engine.obs.tracer.to_chrome()['traceEvents'])} "
              f"trace events to {args.trace} "
              f"(load in https://ui.perfetto.dev); reducer imbalance "
              f"{skew.imbalance:.2f}x, HH hit rate {skew.hh_hit_rate:.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
