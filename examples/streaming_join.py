"""Streaming SharesSkew quickstart: join a drifting Zipf stream.

A 2-way join R(A,B) ⋈ S(B,C) ingested as micro-batches whose skew profile
shifts mid-run: the Zipf-heavy B values move to a different part of the
domain.  Watch the telemetry — the sketches notice the new heavy hitters,
the drift monitor declares the running plan overloaded, and a replan event
fires (carried reducer state is migrated to the new layout).  The final
cumulative (count, checksum) is verified against the batch oracle on the
full concatenated input.

Run:  PYTHONPATH=src python examples/streaming_join.py
"""
import numpy as np

from repro.core import two_way
from repro.mapreduce import oracle_join
from repro.stream import StreamConfig, StreamingJoinEngine


def zipf_batch(rng, shift, n_r=1200, n_s=300, domain=3000, a=1.6):
    """One micro-batch; heavy B values cluster at ``shift`` (mod domain)."""
    b_r = ((rng.zipf(a, n_r) - 1) + shift) % domain
    b_s = ((rng.zipf(a, n_s) - 1) + shift) % domain
    r = np.stack([rng.integers(0, domain, n_r), b_r], 1).astype(np.int64)
    s = np.stack([b_s, rng.integers(0, domain, n_s)], 1).astype(np.int64)
    return {"R": r, "S": s}


def main() -> None:
    rng = np.random.default_rng(0)
    query = two_way()
    engine = StreamingJoinEngine(
        query,
        StreamConfig(q=120, decay=0.5, load_factor=2.0),
        log_fn=print,  # replan events and per-batch telemetry
    )

    print(f"streaming {query} with a skew shift after batch 3\n")
    for i in range(8):
        shift = 0 if i < 4 else 1300  # the drift: heavy values move
        report = engine.ingest(zipf_batch(rng, shift))
        if report.replanned and report.batch > 0:
            print(
                f"  >>> REPLAN (epoch {report.plan_epoch}): {report.drift_reason}; "
                f"migrated {report.migrated_tuples} emissions"
            )

    print(f"\nreplans: {engine.replan_count}, "
          f"cumulative comm: {engine.cumulative_comm} tuples, "
          f"migrated: {engine.total_migrated}")

    count, checksum, _, _ = oracle_join(query, engine.history_data())
    assert (engine.total_count, engine.total_checksum) == (count, checksum)
    print(f"verified: cumulative count/checksum == batch oracle "
          f"({count} results, checksum {checksum:#010x})")


if __name__ == "__main__":
    main()
