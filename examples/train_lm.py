"""End-to-end training driver: data pipeline -> train loop -> checkpoints.

Trains an OLMo-style decoder (or any --arch, reduced or full dims) with
AdamW, checkpoint/restart (atomic, resharding-capable), preemption
handling, and the prefetching token pipeline.  Defaults are CPU-sized; the
flags scale up to the ~100M-parameter configuration
(--preset 100m --steps 300).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 30
      PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
      PYTHONPATH=src python examples/train_lm.py --resume ...   # continue
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.models import build_model
from repro.train import (
    AsyncCheckpointer,
    OptConfig,
    PreemptionGuard,
    init_train_state,
    latest_step,
    load_checkpoint,
    make_train_step,
    restore_tree,
)

PRESETS = {
    # ~2M params: smoke-speed on CPU
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv=2, head_dim=32,
                 d_ff=512, vocab=2048),
    # ~25M params
    "25m": dict(n_layers=6, d_model=384, n_heads=6, n_kv=6, head_dim=64,
                d_ff=1536, vocab=8192),
    # ~100M params (the brief's end-to-end target)
    "100m": dict(n_layers=10, d_model=640, n_heads=10, n_kv=10, head_dim=64,
                 d_ff=2560, vocab=32768),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config(args.arch).reduced(), **PRESETS[args.preset], max_seq=args.seq
    )
    model = build_model(cfg)
    n_params = cfg.n_params()
    print(f"arch={args.arch} preset={args.preset} params≈{n_params/1e6:.1f}M")

    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                        total_steps=args.steps)
    params, opt_state = init_train_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))

    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq, seed=1)
    start = 0
    if args.resume and latest_step(args.ckpt_dir) is not None:
        start, flat = load_checkpoint(args.ckpt_dir)
        tree = restore_tree({"params": params, "opt": opt_state}, flat)
        params, opt_state = tree["params"], tree["opt"]
        pipe.step = start  # exact data resume
        print(f"resumed from step {start}")
    pipe.start()

    ckpt = AsyncCheckpointer(args.ckpt_dir, keep=3)
    with PreemptionGuard() as guard:
        t0 = time.time()
        for step in range(start, args.steps):
            batch = {"tokens": pipe.next_prefetched()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % 10 == 0 or step == args.steps - 1:
                dt = time.time() - t0
                tput = (step - start + 1) * args.batch * args.seq / max(dt, 1e-9)
                print(
                    f"step {step:5d}  loss={float(metrics['loss']):.4f}  "
                    f"gnorm={float(metrics['grad_norm']):.3f}  "
                    f"lr={float(metrics['lr']):.2e}  tok/s={tput:.0f}"
                )
            stop = guard.should_stop
            if stop or (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state})
            if stop:
                print("preemption requested -> checkpointed, exiting cleanly")
                break
    ckpt.wait()
    pipe.stop()
    print("done; resume with --resume")


if __name__ == "__main__":
    main()
