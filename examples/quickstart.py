"""Quickstart: SharesSkew in ~40 lines.

Plan and execute a skewed 2-way join R(A,B) ⋈ S(B,C) on the JAX engine,
verify against the host oracle, and print the communication savings over
the naive partition/broadcast skew join (paper Examples 1-2).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import plan_shares_skew, two_way
from repro.data import paper_2way
from repro.mapreduce import naive_two_way, oracle_join, run_join

# 1. skewed data: |R| = 10 * |S|, one heavy hitter (B=7) in 10% of tuples
rng = np.random.default_rng(0)
data = paper_2way(rng, n_r=20_000, n_s=2_000, domain=30_000)

# 2. plan: detect heavy hitters, build residual joins, solve shares
plan = plan_shares_skew(two_way(), data, q=100)
print(plan.describe())

# 3. execute on the JAX MapReduce engine (map -> shuffle -> reduce)
result = run_join(two_way(), data, plan, cap_factor=4.0)
count, checksum, _, _ = oracle_join(two_way(), data)
assert (result.count, result.checksum) == (count, checksum)
print(f"\njoin count={result.count}  (verified against host oracle)")
print(f"shuffled tuples={result.total_comm}  max reducer load={result.max_load}")

# 4. compare with the naive skew join (partition big side, broadcast small)
hh = next(r for r in plan.residuals if r.combo.pinned)
naive = naive_two_way(
    data["R"], data["S"], np.array([7]),
    k_hh=hh.num_reducers, k_ord=plan.total_reducers - hh.num_reducers,
)
saving = 100 * (1 - result.total_comm / naive.comm_tuples)
print(f"naive shuffle={naive.comm_tuples}  ->  SharesSkew saves {saving:.1f}%")
