"""Walkthrough of the paper's running example (Examples 5-8, §9.2).

3-way join J = R(A,B) ⋈ S(B,E,C) ⋈ T(C,D); B has heavy hitters b1, b2 and
C has c1.  Shows the six residual joins, their cost expressions after
dominance, the reducer grids, and the skew mitigation vs plain Shares —
ending with the distributed (shard_map + all_to_all) execution path.

Run:  PYTHONPATH=src python examples/multiway_join.py
"""
import numpy as np

from repro.core import (
    plan_plain_shares,
    plan_shares_skew,
    share_attributes,
    three_way_paper,
)
from repro.data import paper_3way
from repro.mapreduce import oracle_join, run_distributed, run_join

query = three_way_paper()
print(f"query: {query}")
print(f"share attributes after dominance: {share_attributes(query)}  "
      "(A dom. by B; D dom. by C; E dom. by B,C — paper Ex. 8)\n")

rng = np.random.default_rng(0)
data = paper_3way(rng, n=2_000, domain=20_000)

plan = plan_shares_skew(query, data, q=120)
print(plan.describe())
print()

res = run_join(query, data, plan, cap_factor=5.0)
count, checksum, _, _ = oracle_join(query, data)
assert (res.count, res.checksum) == (count, checksum)
print(f"single-process engine: count={res.count} ✓ oracle  "
      f"max_load={res.max_load} imbalance={res.load_imbalance:.2f}")

plain = plan_plain_shares(query, data, k=plan.total_reducers)
res_plain = run_join(query, data, plain, cap_factor=200.0)
print(f"plain Shares on the same skewed data: max_load={res_plain.max_load} "
      f"imbalance={res_plain.load_imbalance:.2f}  "
      f"(x{res_plain.max_load / max(res.max_load, 1):.1f} worse — Fig 3)")

# distributed path: shard_map + all_to_all over the local device mesh
res_d = run_distributed(query, data, plan, cap_factor=5.0)
assert (res_d.count, res_d.checksum) == (count, checksum)
print(f"distributed engine (all_to_all shuffle): count={res_d.count} ✓")
