"""Batched serving demo: bucketed waves over the universal decode engine.

Builds a small model, submits a mixed bag of requests with different prompt
lengths, and serves them in length-bucketed waves (prefill + greedy decode).
Works identically for KV-cache models and recurrent-state models — swap
--arch rwkv6-3b to serve the attention-free architecture with O(1) state.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch rwkv6-3b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import BucketServer, Request

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="olmo-1b")
ap.add_argument("--requests", type=int, default=10)
ap.add_argument("--max-new", type=int, default=12)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
model = build_model(cfg)
if model.decode_step is None:
    raise SystemExit(f"{args.arch} is encoder-only; it has no decode step")
params = model.init_params(jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
server = BucketServer(model, params, max_batch=4)
for i in range(args.requests):
    plen = int(rng.choice([8, 8, 8, 16, 16, 24]))  # mixed prompt lengths
    server.submit(Request(
        uid=i,
        prompt=rng.integers(0, cfg.vocab, size=plen).astype(np.int32),
        max_new=args.max_new,
    ))

t0 = time.time()
done = server.drain()
dt = time.time() - t0
total_tokens = sum(len(c.tokens) for c in done)
print(f"arch={args.arch}: served {len(done)} requests, "
      f"{total_tokens} tokens in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
for c in sorted(done, key=lambda c: c.uid)[:5]:
    print(f"  req {c.uid}: {c.tokens.tolist()}")
