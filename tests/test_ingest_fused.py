"""Fused ingest pipeline (DESIGN.md §7): kernel vs oracle, fused engine vs
baseline engine — all comparisons bit-for-bit.

The fused path is an *optimization*, never a semantic: every test here
asserts exact integer equality against the unfused implementation that
remains in the tree (``kernels.ref.fused_ingest_ref`` at kernel level,
``StreamConfig(fused_ingest=False)`` at engine level).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import plan_shares_skew, two_way, three_way_paper
from repro.kernels import fused_ingest
from repro.kernels.ingest_fused import fused_ingest_pallas, overlap_profile
from repro.kernels.ref import fused_ingest_ref
from repro.mapreduce.keys import map_phase, static_route_table
from repro.mapreduce.local_join import (
    LocalJoinSpec,
    local_join_count_checksum,
)
from repro.stream import StreamConfig, StreamingJoinEngine
from repro.stream.delta import SortedDeltaIndex


def _zipf_batch(rng, shift, n_r=400, n_s=150, domain=2000, a=1.6):
    b_r = ((rng.zipf(a, n_r) - 1) + shift) % domain
    b_s = ((rng.zipf(a, n_s) - 1) + shift) % domain
    r = np.stack([rng.integers(0, domain, n_r), b_r], 1).astype(np.int64)
    s = np.stack([b_s, rng.integers(0, domain, n_s)], 1).astype(np.int64)
    return {"R": r, "S": s}


def _skewed_plan(query, rng, q=60):
    """A plan with pinned heavy hitters so pins/excludes are exercised."""
    data = {
        r.name: rng.integers(0, 50, size=(600, r.arity)).astype(np.int64)
        for r in query.relations
    }
    # make one value heavy on the first shared column of each relation
    for r in query.relations:
        data[r.name][: 300, -1] = 7
    return plan_shares_skew(query, data, q=q)


# ------------------------------------------------------------- kernel parity
@pytest.mark.parametrize("n", [1, 7, 257, 1000])
@pytest.mark.parametrize("double_buffer", [False, True])
def test_kernel_matches_ref_two_way(n, double_buffer):
    rng = np.random.default_rng(n + double_buffer)
    query = two_way()
    plan = _skewed_plan(query, rng)
    rel = query.relations[0]
    routes = static_route_table(plan, rel)
    rows = jnp.asarray(
        rng.integers(0, 60, size=(n, rel.arity)).astype(np.int32)
    )
    seeds = (11, 222, 3333)
    got = fused_ingest_pallas(
        rows,
        routes=routes,
        sketch_cols=(1,),
        seeds=seeds,
        width=256,
        num_reducers=plan.total_reducers,
        double_buffer=double_buffer,
        interpret=True,
    )
    want = fused_ingest_ref(
        rows,
        routes=routes,
        sketch_cols=(1,),
        seeds=seeds,
        width=256,
        num_reducers=plan.total_reducers,
    )
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("double_buffer", [False, True])
def test_kernel_matches_ref_three_way(double_buffer):
    rng = np.random.default_rng(3)
    query = three_way_paper()
    plan = _skewed_plan(query, rng)
    for rel in query.relations:
        routes = static_route_table(plan, rel)
        rows = jnp.asarray(
            rng.integers(0, 60, size=(333, rel.arity)).astype(np.int32)
        )
        got = fused_ingest_pallas(
            rows,
            routes=routes,
            num_reducers=plan.total_reducers,
            double_buffer=double_buffer,
            interpret=True,
        )
        want = fused_ingest_ref(
            rows, routes=routes, num_reducers=plan.total_reducers
        )
        for g, w in zip(got[:3], want[:3]):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_kernel_dest_matches_map_phase():
    """The kernel's destination block IS the map phase, column layout and
    all — the property the engine's emission ordering relies on."""
    rng = np.random.default_rng(9)
    query = two_way()
    plan = _skewed_plan(query, rng)
    for rel in query.relations:
        rows = jnp.asarray(
            rng.integers(0, 60, size=(500, rel.arity)).astype(np.int32)
        )
        dest, _, _, _ = fused_ingest(
            rows,
            routes=static_route_table(plan, rel),
            num_reducers=plan.total_reducers,
        )
        np.testing.assert_array_equal(
            np.asarray(dest), np.asarray(map_phase(plan, rel, rows))
        )


def test_kernel_sketch_only_and_route_only_modes():
    rng = np.random.default_rng(1)
    rows = jnp.asarray(rng.integers(0, 1000, size=(300, 2)).astype(np.int32))
    seeds = (5, 55)
    # sketch-only: no routes -> dest/rank/counts are None
    dest, rank, counts, cms = fused_ingest(
        rows, sketch_cols=(0, 1), seeds=seeds, width=128
    )
    assert dest is None and rank is None and counts is None
    _, _, _, cms_ref = fused_ingest_ref(
        rows, sketch_cols=(0, 1), seeds=seeds, width=128
    )
    np.testing.assert_array_equal(np.asarray(cms), np.asarray(cms_ref))
    # route-only: no sketch_cols -> cms is None
    query = two_way()
    plan = plan_shares_skew(
        query, {"R": np.asarray(rows), "S": np.asarray(rows)}, q=60
    )
    routes = static_route_table(plan, query.relations[0])
    _, _, _, cms2 = fused_ingest(
        rows, routes=routes, num_reducers=plan.total_reducers
    )
    assert cms2 is None


def test_kernel_counts_are_destination_histogram():
    rng = np.random.default_rng(2)
    query = two_way()
    plan = _skewed_plan(query, rng)
    rel = query.relations[0]
    rows = jnp.asarray(rng.integers(0, 60, size=(700, 2)).astype(np.int32))
    dest, rank, counts, _ = fused_ingest(
        rows,
        routes=static_route_table(plan, rel),
        num_reducers=plan.total_reducers,
    )
    flat = np.asarray(dest).reshape(-1)
    want = np.bincount(flat[flat >= 0], minlength=plan.total_reducers)
    np.testing.assert_array_equal(np.asarray(counts), want)
    # ranks are a permutation of 0..count-1 within each destination
    rk = np.asarray(rank).reshape(-1)
    for d in np.unique(flat[flat >= 0]):
        got = np.sort(rk[flat == d])
        np.testing.assert_array_equal(got, np.arange(got.size))


def test_overlap_profile_roofline_sanity():
    p = overlap_profile(
        n_rows=1500, arity=2, route_w=8, num_reducers=32,
        n_sketch_cols=1, depth=4, width=2048,
    )
    assert p["bound"] in ("dma", "compute")
    assert p["overlapped_us"] <= p["serial_us"]
    assert 1.0 <= p["overlap_speedup"] <= 2.0
    assert p["bytes_in"] > 0 and p["vpu_ops"] > 0


# ------------------------------------------------- sorted delta index parity
def test_sorted_delta_index_matches_einsum_term():
    """probe() reproduces one einsum telescoping term bit-for-bit."""
    rng = np.random.default_rng(0)
    spec = LocalJoinSpec.from_query(two_way())
    assert SortedDeltaIndex.eligible(spec)
    k, cap_l, cap_r = 13, 64, 32
    for trial in range(5):
        def emissions(n):
            dest = rng.integers(0, k, size=n).astype(np.int32)
            rows = rng.integers(0, 30, size=(n, 2)).astype(np.int32)
            return dest, rows

        dl, rl = emissions(500)
        dr, rr = emissions(200)
        idx = SortedDeltaIndex(spec)
        idx.rebuild("R", dl, rl)
        cnt, chk = idx.probe("R", "S", dr, rr)

        # einsum reference over the same emissions, binned
        def to_bins(dest, rows, cap):
            bins = np.zeros((k, cap, 2), np.int32)
            valid = np.zeros((k, cap), bool)
            order = np.argsort(dest, kind="stable")
            ds, rs = dest[order], rows[order]
            first = np.searchsorted(ds, ds, side="left")
            rank = np.arange(ds.size) - first
            bins[ds, rank] = rs
            valid[ds, rank] = True
            return jnp.asarray(bins), jnp.asarray(valid)

        bl, vl = to_bins(dl, rl, cap_l)
        br, vr = to_bins(dr, rr, cap_r)
        want_cnt, want_chk = local_join_count_checksum(
            spec, {"R": bl, "S": br}, {"R": vl, "S": vr}
        )
        assert (cnt, chk) == (int(want_cnt), int(want_chk))


def test_sorted_delta_index_append_equals_rebuild():
    rng = np.random.default_rng(5)
    spec = LocalJoinSpec.from_query(two_way())
    idx_a = SortedDeltaIndex(spec)
    idx_b = SortedDeltaIndex(spec)
    dests, rowss = [], []
    for _ in range(4):
        dest = rng.integers(0, 9, size=120).astype(np.int32)
        rows = rng.integers(0, 40, size=(120, 2)).astype(np.int32)
        dests.append(dest)
        rowss.append(rows)
        idx_a.append("R", dest, rows)
    idx_b.rebuild("R", np.concatenate(dests), np.concatenate(rowss))
    np.testing.assert_array_equal(
        idx_a._keys_by_rel["R"], idx_b._keys_by_rel["R"]
    )
    # weights may be permuted within equal keys, but group sums (the only
    # thing probe reads) must match; keys equal => same group boundaries
    pd = rng.integers(0, 9, size=60).astype(np.int32)
    pr = rng.integers(0, 40, size=(60, 2)).astype(np.int32)
    assert idx_a.probe("R", "S", pd, pr) == idx_b.probe("R", "S", pd, pr)


def test_sorted_delta_index_rejects_multiway():
    spec = LocalJoinSpec.from_query(three_way_paper())
    assert not SortedDeltaIndex.eligible(spec)
    with pytest.raises(ValueError):
        SortedDeltaIndex(spec)


# ----------------------------------------------------------- engine parity
def _run_pair(query, batches, **cfg_kw):
    cfg = dict(q=60, decay=0.5, load_factor=2.0)
    cfg.update(cfg_kw)
    base = StreamingJoinEngine(query, StreamConfig(**cfg))
    fused = StreamingJoinEngine(
        query, StreamConfig(fused_ingest=True, **cfg)
    )
    reports = []
    for batch in batches:
        rb = base.ingest(batch)
        rf = fused.ingest(batch)
        reports.append((rb, rf))
    return base, fused, reports


def test_engine_fused_parity_on_drifting_zipf():
    """The headline invariant: fused ingest is bit-identical to the
    baseline — per-batch reports, Count-Min tables, and the packed
    per-reducer buffers — across drift, replans, and migration."""
    rng = np.random.default_rng(0)
    batches = [
        _zipf_batch(rng, shift=0 if i < 3 else 900, a=2.0 if i < 3 else 1.4)
        for i in range(6)
    ]
    base, fused, reports = _run_pair(two_way(), batches)
    assert any(rb.replanned for rb, _ in reports[1:]), "stream must drift"
    for i, (rb, rf) in enumerate(reports):
        assert rb == rf, f"batch {i} reports diverge"
    # packed per-reducer buffers: same bins, validity, occupancy
    for nm in ("R", "S"):
        b0, v0, o0 = base._state[nm]
        b1, v1, o1 = fused._state[nm]
        np.testing.assert_array_equal(o0, o1)
        np.testing.assert_array_equal(v0, v1)
        np.testing.assert_array_equal(b0, b1)
    # sketch tables: bit-for-bit (integer increments exact in float64)
    for key in base.tracker._cms:
        np.testing.assert_array_equal(
            base.tracker._cms[key].table, fused.tracker._cms[key].table
        )
    assert base.tracker._ss.keys() == fused.tracker._ss.keys()
    for a in base.tracker._ss:
        assert base.tracker._ss[a].counts == fused.tracker._ss[a].counts
    assert fused.fused_batches == len(batches), "fused path silently skipped"


def test_engine_fused_parity_three_way():
    """n-way queries keep the einsum delta path under fused routing; the
    cumulative fingerprint still matches the baseline exactly."""
    rng = np.random.default_rng(1)
    query = three_way_paper()
    batches = []
    for i in range(3):
        b = ((rng.zipf(1.6, 250) - 1) + (0 if i < 2 else 400)) % 1000
        c = rng.integers(0, 1000, 250)
        batches.append(
            {
                "R": np.stack([rng.integers(0, 1000, 250), b], 1),
                "S": np.stack([b, rng.integers(0, 1000, 250), c], 1),
                "T": np.stack([c, rng.integers(0, 1000, 250)], 1),
            }
        )
    base, fused, reports = _run_pair(query, batches, q=40)
    for i, (rb, rf) in enumerate(reports):
        assert rb == rf, f"batch {i} reports diverge"
    assert fused.fused_batches == len(batches)


def test_engine_fused_empty_and_lopsided_batches():
    rng = np.random.default_rng(2)
    full = _zipf_batch(rng, 0)
    empty = {"R": np.empty((0, 2), np.int64), "S": np.empty((0, 2), np.int64)}
    lopsided = {"R": full["R"], "S": np.empty((0, 2), np.int64)}
    base, fused, reports = _run_pair(two_way(), [full, empty, lopsided])
    for i, (rb, rf) in enumerate(reports):
        assert rb == rf, f"batch {i} reports diverge"
    assert fused.fused_batches == 3


def test_property_total_comm_invariant_under_fusion():
    """Property sweep (seeded, no external dependency): across random
    stream shapes, drift points, and engine knobs, fusion never changes
    ``BatchReport.total_comm`` — the shuffle volume the paper's cost model
    optimizes is untouched by how fast the pass runs."""
    rng = np.random.default_rng(1234)
    for trial in range(4):
        n_r = int(rng.integers(50, 400))
        n_s = int(rng.integers(20, 200))
        domain = int(rng.integers(200, 3000))
        a = float(rng.uniform(1.3, 2.2))
        shift = int(rng.integers(0, domain))
        n_batches = int(rng.integers(2, 5))
        q = float(rng.choice([30, 60, 120]))
        batches = [
            _zipf_batch(
                rng,
                shift=0 if i < n_batches // 2 else shift,
                n_r=n_r,
                n_s=n_s,
                domain=domain,
                a=a,
            )
            for i in range(n_batches)
        ]
        base, fused, reports = _run_pair(two_way(), batches, q=q)
        for i, (rb, rf) in enumerate(reports):
            assert rb.total_comm == rf.total_comm, (
                f"trial {trial} batch {i}: comm diverged "
                f"({rb.total_comm} != {rf.total_comm})"
            )
            assert rb.comm_tuples == rf.comm_tuples


# ------------------------------------------- dense (dynamic-operand) routes
@pytest.mark.parametrize("double_buffer", [False, True])
def test_dense_kernel_matches_static_variant(double_buffer):
    """The dense route encoding is bit-identical to the static-route
    kernel on dest/rank/counts/cms, including pins and excludes."""
    from repro.kernels.ingest_fused import (
        dense_route_encoding,
        fused_ingest_dense_pallas,
        route_width,
    )

    rng = np.random.default_rng(17)
    for query in (two_way(), three_way_paper()):
        plan = _skewed_plan(query, rng)
        seeds = (11, 222, 3333)
        for rel in query.relations:
            routes = static_route_table(plan, rel)
            n = 311
            rows = jnp.asarray(
                rng.integers(0, 60, size=(n, rel.arity)).astype(np.int32)
            )
            d1, r1, c1, m1 = fused_ingest_pallas(
                rows, routes=routes, sketch_cols=(rel.arity - 1,),
                seeds=seeds, width=128,
                num_reducers=plan.total_reducers,
                block=128, double_buffer=double_buffer,
            )
            w = route_width(routes)
            wp = 1 << max(0, int(w - 1).bit_length())
            k_pad = max(-(-plan.total_reducers // 128) * 128, 128)
            enc = dense_route_encoding(routes, rel.arity, wp, max_values=8)
            d2, r2, c2, m2 = fused_ingest_dense_pallas(
                rows, enc, sketch_cols=(rel.arity - 1,),
                seeds=seeds, width=128, k_pad=k_pad,
                block=128, double_buffer=double_buffer,
            )
            np.testing.assert_array_equal(d1, np.asarray(d2)[:n, :w])
            np.testing.assert_array_equal(r1, np.asarray(r2)[:n, :w])
            np.testing.assert_array_equal(
                c1, np.asarray(c2)[: plan.total_reducers]
            )
            np.testing.assert_array_equal(m1, m2)


def test_dense_kernel_reuses_executable_across_replans():
    """The whole point of the dense encoding: two DIFFERENT route tables
    whose padded shapes agree must hit ONE compiled executable (the
    static-route kernel recompiles per plan — the replan ingest spike)."""
    from repro.kernels.ingest_fused import (
        dense_route_encoding,
        route_width,
    )
    from repro.kernels.ops import fused_ingest_dense

    rng = np.random.default_rng(23)
    query = two_way()
    rel = query.relations[0]
    plans = []
    for hot in (7, 31):
        data = {
            r.name: rng.integers(0, 50, size=(600, r.arity)).astype(np.int64)
            for r in query.relations
        }
        for r in query.relations:
            data[r.name][:300, -1] = hot
        plans.append(plan_shares_skew(query, data, q=60))
    tables = [static_route_table(p, rel) for p in plans]
    assert tables[0] != tables[1], "need genuinely different route tables"
    wp = max(
        1 << max(0, int(route_width(t) - 1).bit_length()) for t in tables
    )
    rows = jnp.asarray(
        rng.integers(0, 60, size=(200, rel.arity)).astype(np.int32)
    )
    before = fused_ingest_dense._cache_size()
    for t in tables:
        enc = dense_route_encoding(t, rel.arity, wp, max_values=8)
        fused_ingest_dense(
            rows, enc, sketch_cols=(1,), seeds=(11, 22), width=128,
            k_pad=128, block=128, double_buffer=False,
        )[0].block_until_ready()
    assert fused_ingest_dense._cache_size() - before <= 1, (
        "a second route table with identical padded shapes recompiled"
    )


def test_engine_dynamic_routes_bit_identical_to_static():
    """StreamConfig(fused_dynamic_routes=True) — the default — must be
    bit-identical to the static-route fused engine across drift/replans."""
    rng = np.random.default_rng(29)
    batches = [
        _zipf_batch(rng, shift=0 if i < 3 else 900, a=2.0 if i < 3 else 1.4)
        for i in range(6)
    ]
    cfg = dict(q=60, decay=0.5, load_factor=2.0, fused_ingest=True)
    static = StreamingJoinEngine(
        two_way(), StreamConfig(fused_dynamic_routes=False, **cfg)
    )
    dyn = StreamingJoinEngine(
        two_way(), StreamConfig(fused_dynamic_routes=True, **cfg)
    )
    for i, batch in enumerate(batches):
        rs = static.ingest(batch)
        rd = dyn.ingest(batch)
        assert rs == rd, f"batch {i} reports diverge"
    assert any(r.replanned for r in dyn.reports[1:]), "stream must drift"
    for nm in ("R", "S"):
        for a, b in zip(static._state[nm], dyn._state[nm]):
            np.testing.assert_array_equal(a, b)
    for key in static.tracker._cms:
        np.testing.assert_array_equal(
            static.tracker._cms[key].table, dyn.tracker._cms[key].table
        )
