"""Launch layer: sharding rules, HLO analysis, dry-run cell, elastic restart."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import HloModule, analyze
from repro.launch.sharding import batch_specs, cache_specs, opt_specs, param_specs


# ------------------------------------------------------------ sharding rules
def test_param_specs_suffix_rules():
    params = {
        "embed": {"table": jnp.zeros((256000, 128))},
        "blocks": {
            "attn": {"wq": jnp.zeros((4, 128, 256)), "wo": jnp.zeros((4, 256, 128))},
            "mlp": {"w_up": jnp.zeros((4, 128, 512)), "w_down": jnp.zeros((4, 512, 128))},
            "experts": {"w_gate": jnp.zeros((4, 32, 128, 64))},
            "ln1": {"scale": jnp.zeros((128,))},
        },
    }
    specs = param_specs(params, model_size=16)
    assert specs["embed"]["table"] == P("model", None)
    assert specs["blocks"]["attn"]["wq"] == P(None, None, "model")
    assert specs["blocks"]["attn"]["wo"] == P(None, "model", None)
    assert specs["blocks"]["mlp"]["w_up"] == P(None, None, "model")
    assert specs["blocks"]["mlp"]["w_down"] == P(None, "model", None)
    assert specs["blocks"]["experts"]["w_gate"] == P("model", None, None, None) or \
        specs["blocks"]["experts"]["w_gate"] == P(None, "model", None, None)
    assert specs["blocks"]["ln1"]["scale"] == P()


def test_param_specs_indivisible_replicates():
    params = {"lm_head": {"w": jnp.zeros((128, 49155))}}  # 49155 % 16 != 0
    specs = param_specs(params, model_size=16)
    # falls back: vocab not divisible -> d gets sharded or replicated, never crash
    assert isinstance(specs["lm_head"]["w"], P)


def test_opt_specs_zero1_shards_replicated_moments():
    params = {"big": jnp.zeros((1 << 11, 1 << 10))}  # 2M elems, replicated spec
    p_spec = {"big": P()}
    o = opt_specs(p_spec, params, data_size=16, zero1=True)
    assert o["m"]["big"] == P("data", None)
    o2 = opt_specs(p_spec, params, data_size=16, zero1=False)
    assert o2["m"]["big"] == P()


def test_batch_and_cache_specs():
    b = batch_specs({"tokens": jnp.zeros((32, 128), jnp.int32)}, ("data",))
    assert b["tokens"] == P(("data",), None)
    cache = {"k": jnp.zeros((4, 32, 16, 1024, 128))}  # [L,B,H,S,hd]
    c = cache_specs(cache, ("data",), model_size=16)
    assert c["k"] == P(None, ("data",), "model", None, None)
    # B=1 long-context: shard sequence instead
    cache1 = {"k": jnp.zeros((4, 1, 4, 524288, 128))}
    c1 = cache_specs(cache1, ("data",), model_size=16)
    assert c1["k"][3] in ("data", ("data",))


# -------------------------------------------------------------- hlo analysis
_TOY_HLO = """
HloModule toy

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %dotx = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%dotx), replica_groups=[4,2]<=[8], to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %tup = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_hlo_analysis_trip_count_multiplies():
    res = analyze(_TOY_HLO)
    # one 8x8x8 matmul per iteration, 10 iterations
    assert res["flops"] == pytest.approx(10 * 2 * 8 * 8 * 8, rel=0.2)
    ar = res["collectives"]["all-reduce"]
    assert ar["count"] == 10
    assert ar["payload_bytes"] == 10 * 8 * 8 * 4
    # ring factor for group size 2: 2*(2-1)/2 = 1.0
    assert ar["wire_bytes"] == pytest.approx(10 * 8 * 8 * 4 * 1.0)


def test_hlo_analysis_handles_tuple_shapes():
    mod = HloModule(_TOY_HLO)
    assert mod.entry == "main"
    assert "body" in mod.computations


# ------------------------------------------------------------- dry-run cell
def test_dryrun_single_cell_subprocess(tmp_path):
    """One real dry-run cell end to end (512 fake devices, 16x16 mesh)."""
    code = (
        "import sys; sys.argv=['x','--arch','olmo-1b','--shape','train_4k',"
        f"'--out','{tmp_path}'];"
        "from repro.launch import dryrun; dryrun.main()"
    )
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    import json, os

    files = os.listdir(tmp_path)
    assert len(files) == 1
    rec = json.load(open(tmp_path / files[0]))
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert rec["flops"] > 1e13  # trip-count-aware, not body-once
    assert rec["collective_wire_bytes"] > 0


# ------------------------------------------------------------ elastic restart
_ELASTIC_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import build_model
from repro.launch.sharding import param_specs, named
from repro.train import (OptConfig, init_train_state, make_train_step,
                         save_checkpoint, load_checkpoint, restore_tree)
from repro.train.elastic import plan_mesh_shape

cfg = get_config("olmo-1b").reduced()
model = build_model(cfg)
opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
step = jax.jit(make_train_step(model, opt))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32)}

# phase 1: mesh (4 data, 2 model)
mesh1 = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
params, opt_state = init_train_state(model, jax.random.PRNGKey(0))
p_spec = param_specs(params, model_size=2)
with mesh1:
    params = jax.device_put(params, named(mesh1, p_spec))
    for _ in range(2):
        params, opt_state, m = step(params, opt_state, batch)
loss_before = float(m["loss"])
save_checkpoint("/tmp/elastic_ckpt", 2, {"params": params, "opt": opt_state})

# phase 2: "lose" half the devices -> mesh (2 data, 2 model); resharding restore
plan = plan_mesh_shape(4, model_parallel=2, chips_per_pod=8)
assert plan.model == 2 and plan.data * plan.model <= 4
mesh2 = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
_, flat = load_checkpoint("/tmp/elastic_ckpt")
with mesh2:
    tree = restore_tree({"params": params, "opt": opt_state}, flat,
                        {"params": named(mesh2, p_spec),
                         "opt": jax.tree.map(lambda _: NamedSharding(mesh2, P()), opt_state)})
    p2, o2 = tree["params"], tree["opt"]
    p2, o2, m2 = step(p2, o2, batch)
assert np.isfinite(float(m2["loss"]))
print("ELASTIC_OK", loss_before, float(m2["loss"]))
"""


def test_elastic_shrink_restart_subprocess():
    proc = subprocess.run(
        [sys.executable, "-c", _ELASTIC_SNIPPET],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ELASTIC_OK" in proc.stdout
