"""Reducer-loss recovery tests (DESIGN.md §5).

The contract under test: with ``RecoveryPolicy(n_hosts=H)`` the engine
multiplexes logical reducers over H simulated hosts; killing hosts
mid-stream is detected at the next batch boundary and recovered WITHOUT a
checkpoint restore — lineage replay rebuilds exactly the lost reducers'
carried state from the retained window, the window fingerprint matches
both the einsum oracle and ``recompute_distributed(window=True)``
bit-for-bit, replayed tuples never exceed the lost reducers' retained
share, sustained loss degrades elastically (smaller grid, tighter
admission), and loss beyond the survivable grid is an explicit
``RecoveryExhaustedError`` — never a silently wrong window.
"""
import numpy as np
import pytest

from repro.core import (
    make_query,
    plan_shares_skew,
    solve_shares,
    two_way,
    two_way_skew_shares,
)
from repro.core.planner import repair_plan
from repro.core.shares import reproject_solution
from repro.mapreduce import oracle_join
from repro.mapreduce.straggler import FailureDetector
from repro.stream import (
    AdmissionPolicy,
    HostTracker,
    RecoveryExhaustedError,
    RecoveryPolicy,
    RetentionPolicy,
    StreamConfig,
    StreamingJoinEngine,
)
from repro.testing import FaultInjector, FaultSpec

pytestmark = pytest.mark.recovery


def _zipf_batch(rng, shift, n_r=240, n_s=80, domain=600, a=1.6):
    """Skewed 2-way batch; ``shift`` rotates the hot keys (drift)."""
    b_r = ((rng.zipf(a, n_r) - 1) + shift) % domain
    b_s = ((rng.zipf(a, n_s) - 1) + shift) % domain
    r = np.stack([rng.integers(0, domain, n_r), b_r], 1).astype(np.int64)
    s = np.stack([b_s, rng.integers(0, domain, n_s)], 1).astype(np.int64)
    return {"R": r, "S": s}


def _cfg(**kw):
    kw.setdefault("q", 60)
    kw.setdefault("decay", 0.5)
    kw.setdefault("load_factor", 2.0)
    kw.setdefault("retention", RetentionPolicy(window_batches=4))
    kw.setdefault("recovery", RecoveryPolicy(n_hosts=8))
    return StreamConfig(**kw)


def _assert_window_exact(eng):
    """The acceptance invariant: maintained fingerprint == einsum oracle
    == distributed recompute, bit-for-bit."""
    count, checksum, _, _ = oracle_join(eng.query, eng.history_data())
    assert (eng.window_count, eng.window_checksum) == (count, checksum)
    # degraded plans concentrate the window on few reducers; generous
    # caps keep the cross-check overflow-free so the comparison is exact
    res = eng.recompute_distributed(
        window=True, cap_factor=24.0, route_cap_factor=24.0
    )
    assert res.overflow == 0
    assert (res.count, res.checksum) == (count, checksum)


# ---------------------------------------------------------------- replay
def test_single_host_loss_replays_exactly():
    """Kill one host on a drifting Zipf stream: recovery runs in replay
    mode (plan untouched), rebuilds only the lost reducers' bins from the
    retained window, and the window stays exact afterwards."""
    rng = np.random.default_rng(0)
    eng = StreamingJoinEngine(two_way(), _cfg())
    for i in range(5):
        eng.ingest(_zipf_batch(rng, 0 if i < 3 else 300))
    rep = eng.fail_hosts([2])
    assert rep is not None
    assert rep.mode == "replay"
    assert rep.lost_hosts == (2,)
    assert rep.lost_reducers >= 1
    assert rep.verified
    # lineage replay ships exactly the lost reducers' retained share,
    # never more (acceptance: replayed <= lost share)
    assert rep.replayed_tuples == rep.lost_share_tuples
    assert rep.reducers_before == rep.reducers_after  # plan untouched
    _assert_window_exact(eng)
    for i in range(4):  # the engine keeps streaming after recovery
        eng.ingest(_zipf_batch(rng, 300))
    _assert_window_exact(eng)


def test_multi_host_loss_single_boundary():
    """Losing several hosts at one boundary is one recovery event; the
    replay covers every lost reducer and stays exact."""
    rng = np.random.default_rng(1)
    eng = StreamingJoinEngine(two_way(), _cfg())
    for i in range(6):
        eng.ingest(_zipf_batch(rng, 0 if i < 3 else 200))
    rep = eng.fail_hosts([0, 5])
    assert rep.mode == "replay"
    assert rep.lost_hosts == (0, 5)
    assert rep.replayed_tuples == rep.lost_share_tuples
    assert rep.verified
    assert len(eng.recoveries) == 1
    _assert_window_exact(eng)


def test_replay_without_retention_uses_full_history():
    """Retention off: the lineage source is the full retained history (the
    whole stream) — recovery still never touches a checkpoint."""
    rng = np.random.default_rng(2)
    eng = StreamingJoinEngine(
        two_way(), _cfg(retention=RetentionPolicy())  # unbounded
    )
    for _ in range(5):
        eng.ingest(_zipf_batch(rng, 0))
    rep = eng.fail_hosts([3])
    assert rep.mode == "replay" and rep.verified
    assert rep.replayed_tuples == rep.lost_share_tuples
    count, checksum, _, _ = oracle_join(eng.query, eng.history_data())
    assert (eng.window_count, eng.window_checksum) == (count, checksum)


def test_fused_path_recovers_identically():
    """The fused-ingest hot path carries a sorted delta index alongside the
    bins; recovery must drop + replay both representations coherently."""
    rng = np.random.default_rng(3)
    eng = StreamingJoinEngine(two_way(), _cfg(fused_ingest=True))
    for i in range(5):
        eng.ingest(_zipf_batch(rng, 0 if i < 3 else 300))
    rep = eng.fail_hosts([2])
    assert rep.mode == "replay" and rep.verified
    for _ in range(4):
        eng.ingest(_zipf_batch(rng, 300))
    _assert_window_exact(eng)


# ---------------------------------------------------------------- detection
def test_injected_host_loss_detected_at_deadline():
    """An injector-scheduled ``host_loss`` silences heartbeats at its
    batch; the deadline declares the host at that same boundary (deadline
    1 batch, registration backfilled one batch behind) and recovery runs
    before the batch is admitted."""
    rng = np.random.default_rng(4)
    inj = FaultInjector(
        [FaultSpec(kind="host_loss", target="host", host_id=3, batch=4)]
    )
    eng = StreamingJoinEngine(two_way(), _cfg())
    eng.arm_faults(inj)
    for i in range(8):
        eng.ingest(_zipf_batch(rng, 0 if i < 4 else 300))
    assert len(eng.recoveries) == 1
    assert eng.recoveries[0].batch == 4
    assert eng.recoveries[0].lost_hosts == (3,)
    assert 3 not in eng._hosts.alive
    inj.assert_all_resolved()
    assert inj.report().recovered == 1
    _assert_window_exact(eng)


def test_partition_heals_and_host_rejoins_empty():
    """A ``partition`` silences a host like a loss — its reducers are
    recovered onto survivors — but after ``heal_after`` batches the host
    rejoins the pool as an empty spare."""
    rng = np.random.default_rng(5)
    inj = FaultInjector(
        [FaultSpec(kind="partition", target="host", host_id=1, batch=3,
                   heal_after=2)]
    )
    eng = StreamingJoinEngine(two_way(), _cfg())
    eng.arm_faults(inj)
    for i in range(4):
        eng.ingest(_zipf_batch(rng, 0))
    assert len(eng.recoveries) == 1  # partition looks like loss at first
    assert 1 not in eng._hosts.alive
    for i in range(3):
        eng.ingest(_zipf_batch(rng, 0))
    assert 1 in eng._hosts.alive  # healed and rejoined
    inj.assert_all_resolved()
    _assert_window_exact(eng)


def test_failure_detector_unit():
    det = FailureDetector(deadline=2)
    det.heartbeat("a", 0)
    det.heartbeat("b", 1)
    assert det.overdue(1) == []
    assert det.overdue(2) == ["a"]
    assert det.overdue(3) == ["a", "b"]  # oldest lag first
    det.heartbeat("a", 3)
    assert det.overdue(3) == ["b"]
    det.deregister("b")
    assert det.overdue(10) == ["a"]
    assert det.members == ("a",)
    with pytest.raises(ValueError):
        FailureDetector(deadline=0)


# ---------------------------------------------------------------- degrade
def test_sustained_loss_degrades_elastically():
    """Dropping below ``degrade_below`` survivors repairs the plan onto a
    smaller grid (same HH combinations) and tightens admission budgets by
    the surviving-capacity fraction — and the window stays exact."""
    rng = np.random.default_rng(6)
    eng = StreamingJoinEngine(
        two_way(),
        _cfg(admission=AdmissionPolicy(headroom=4.0)),
    )
    for i in range(5):
        eng.ingest(_zipf_batch(rng, 0 if i < 3 else 300))
    combos_before = tuple(r.combo for r in eng.plan.residuals)
    budgets_before = eng._controller.budgets(eng.plan)
    first = eng.fail_hosts([0, 1])  # 6/8 alive: still replay mode
    assert first is not None and first.mode == "replay"
    rep = eng.fail_hosts([2, 3, 4])  # 3/8 alive: below 0.5 -> degrade
    assert rep.mode == "degrade"
    assert rep.reducers_after < rep.reducers_before
    assert rep.migrated_tuples > 0  # full rebuild re-routed the window
    assert rep.verified
    # HH combinations never move during repair
    assert tuple(r.combo for r in eng.plan.residuals) == combos_before
    # admission clamps to surviving capacity
    assert eng._controller.capacity_factor == pytest.approx(3 / 8)
    budgets_after = eng._controller.budgets(eng.plan)
    assert all(
        budgets_after[nm] <= budgets_before[nm] for nm in budgets_after
    )
    for _ in range(3):
        eng.ingest(_zipf_batch(rng, 300))
    _assert_window_exact(eng)


def test_exhaustion_is_loud_and_sticky():
    """Loss beyond the survivable grid raises ``RecoveryExhaustedError``
    at the boundary AND on every subsequent ingest — an exhausted engine
    never produces another (possibly wrong) answer."""
    rng = np.random.default_rng(7)
    eng = StreamingJoinEngine(
        two_way(), _cfg(recovery=RecoveryPolicy(n_hosts=4, min_hosts=2))
    )
    for _ in range(4):
        eng.ingest(_zipf_batch(rng, 0))
    with pytest.raises(RecoveryExhaustedError, match="min_hosts"):
        eng.fail_hosts([0, 1, 2])  # 1 survivor < min_hosts=2
    with pytest.raises(RecoveryExhaustedError):
        eng.ingest(_zipf_batch(rng, 0))


# ------------------------------------------------------------- plan repair
@pytest.fixture(scope="module")
def skewed_plan():
    rng = np.random.default_rng(0)
    n, domain = 3000, 2000
    heavy = np.concatenate([np.full(600, 5), np.full(500, 17), np.full(400, 42)])
    b_r = np.concatenate([heavy, rng.integers(0, domain, n - heavy.size)])
    r = np.stack([rng.integers(0, domain, n), b_r], 1).astype(np.int64)
    b_s = np.concatenate(
        [np.full(120, 5), np.full(100, 17), np.full(80, 42),
         rng.integers(0, domain, 300)]
    )
    s = np.stack([b_s, rng.integers(0, domain, 600)], 1).astype(np.int64)
    plan = plan_shares_skew(two_way(), {"R": r, "S": s}, q=150)
    assert len(plan.residuals) >= 3
    return plan


def test_repair_plan_shrinks_in_place(skewed_plan):
    k_old = skewed_plan.total_reducers
    repaired = repair_plan(skewed_plan, k_old // 2)
    assert repaired.total_reducers <= k_old // 2
    # identical query, q, HH values, and combination list — zero movement
    assert repaired.query is skewed_plan.query
    assert repaired.q == skewed_plan.q
    assert repaired.hh_values == skewed_plan.hh_values
    assert [r.combo for r in repaired.residuals] == [
        r.combo for r in skewed_plan.residuals
    ]
    # every residual keeps >= 1 reducer, offsets re-packed contiguously
    offset = 0
    for r in repaired.residuals:
        assert r.num_reducers >= 1
        assert r.reducer_offset == offset
        offset += r.num_reducers


def test_repair_plan_identity_and_exhaustion(skewed_plan):
    assert repair_plan(skewed_plan, skewed_plan.total_reducers) is skewed_plan
    assert repair_plan(skewed_plan, 10**6) is skewed_plan
    with pytest.raises(ValueError, match="residuals"):
        repair_plan(skewed_plan, len(skewed_plan.residuals) - 1)


def test_repaired_plan_still_joins_exactly(skewed_plan):
    """A repaired plan is a valid plan: executing it reproduces the exact
    join fingerprint of the incumbent."""
    from repro.mapreduce import run_join

    rng = np.random.default_rng(8)
    data = {
        "R": np.stack(
            [rng.integers(0, 2000, 800), rng.integers(0, 50, 800)], 1
        ).astype(np.int64),
        "S": np.stack(
            [rng.integers(0, 50, 300), rng.integers(0, 2000, 300)], 1
        ).astype(np.int64),
    }
    base = run_join(two_way(), data, skewed_plan, cap_factor=8.0)
    repaired = repair_plan(skewed_plan, skewed_plan.total_reducers // 2)
    res = run_join(two_way(), data, repaired, cap_factor=8.0)
    assert res.overflow == 0
    assert (res.count, res.checksum) == (base.count, base.checksum)


def test_reproject_solution_scaling():
    """Shrinking a 2-way skew solution follows the closed form: shares
    scale by (k'/k)^(1/m) along the constraint normal, landing on the
    interior optimum at the new budget exactly."""
    q = make_query({"R": ("A", "B"), "S": ("B", "C")})
    sizes = {"R": 4_000.0, "S": 1_000.0}  # interior optimum at both k
    sol = solve_shares(q, sizes, k=64, fixed_to_one=frozenset({"B"}))
    shrunk = reproject_solution(sol, 16.0)
    assert shrunk.k == 16.0
    assert np.prod(list(shrunk.int_shares.values())) <= 16
    # the 2-way closed form at k'=16: x = sqrt(k r/s) = 8, y = 2
    a, c = two_way_skew_shares(sizes["R"], sizes["S"], 16)
    assert shrunk.shares["A"] == pytest.approx(a, rel=1e-4)
    assert shrunk.shares["C"] == pytest.approx(c, rel=1e-4)
    direct = solve_shares(q, sizes, k=16, fixed_to_one=frozenset({"B"}))
    assert shrunk.cost == pytest.approx(direct.cost, rel=1e-4)


def test_reproject_solution_boundary_waterfill():
    """When scaling would push a share below 1, it clamps there and its
    budget redistributes over the free shares — the product never exceeds
    the new budget and the projection matches the direct solve."""
    q = make_query({"R": ("A", "B"), "S": ("B", "C")})
    sizes = {"R": 10_000.0, "S": 400.0}  # C hits the x >= 1 boundary
    sol = solve_shares(q, sizes, k=64, fixed_to_one=frozenset({"B"}))
    shrunk = reproject_solution(sol, 16.0)
    assert np.prod(list(shrunk.shares.values())) <= 16 + 1e-9
    assert np.prod(list(shrunk.int_shares.values())) <= 16
    assert shrunk.shares["C"] == 1.0
    assert shrunk.shares["A"] == pytest.approx(16.0, rel=1e-6)
    direct = solve_shares(q, sizes, k=16, fixed_to_one=frozenset({"B"}))
    assert shrunk.cost == pytest.approx(direct.cost, rel=1e-4)


def test_reproject_solution_grow_is_identity():
    q = make_query({"R": ("A", "B"), "S": ("B", "C")})
    sol = solve_shares(q, {"R": 1000.0, "S": 1000.0}, k=16)
    same = reproject_solution(sol, 16.0)
    assert same.shares == sol.shares
    grown = reproject_solution(sol, 64.0)  # never grows shares
    assert grown.shares == sol.shares and grown.k == 64.0


# ------------------------------------------------------------ host tracker
def test_host_tracker_placement_and_ladder():
    pol = RecoveryPolicy(n_hosts=4)
    t = HostTracker(pol)
    t.assign(8)
    assert t.host_of.tolist() == [0, 0, 1, 1, 2, 2, 3, 3]
    assert t.reducers_on([1]).tolist() == [2, 3]
    t.silence(1)  # heartbeats stop; still in the pool
    assert t.beating() == [0, 2, 3]
    t.declare_lost([1])
    assert t.alive == [0, 2, 3]
    t.reassign(np.array([2, 3]))
    assert all(h in t.alive for h in t.host_of[[2, 3]])
    # partition: silenced with a heal batch -> fenced on declare, rejoins
    t.silence(2, heal_at=7)
    t.declare_lost([2])
    assert t.alive == [0, 3] and t.fenced == {2: 7}
    assert t.heal_due(6) == []
    assert t.heal_due(7) == [2]
    assert t.alive == [0, 2, 3]
    # round-trip
    t2 = HostTracker(pol)
    t2.load_state_dict(t.state_dict())
    assert t2.alive == t.alive
    assert t2.fenced == t.fenced
    assert t2.silenced == t.silenced
    assert t2.host_of.tolist() == t.host_of.tolist()


def test_recovery_policy_validation():
    with pytest.raises(ValueError):
        RecoveryPolicy(n_hosts=0)
    with pytest.raises(ValueError):
        RecoveryPolicy(n_hosts=4, deadline_batches=0)
    with pytest.raises(ValueError):
        RecoveryPolicy(n_hosts=4, degrade_below=1.5)
    with pytest.raises(ValueError):
        RecoveryPolicy(n_hosts=4, min_hosts=0)
    with pytest.raises(ValueError):
        HostTracker(RecoveryPolicy())  # disabled policy
    assert not RecoveryPolicy().enabled
    assert RecoveryPolicy(n_hosts=2).enabled


def test_recovery_disabled_engine_refuses_fail_hosts():
    eng = StreamingJoinEngine(two_way(), _cfg(recovery=RecoveryPolicy()))
    with pytest.raises(RuntimeError, match="recovery is disabled"):
        eng.fail_hosts([0])


# ------------------------------------------------------------- checkpoints
def test_recovery_state_survives_checkpoint(tmp_path):
    """Recovery history, host liveness, and admission capacity all round-
    trip through save/restore; the restored engine streams on in lockstep."""
    rng = np.random.default_rng(9)
    cfg = _cfg(admission=AdmissionPolicy(headroom=4.0),
               recovery=RecoveryPolicy(n_hosts=8, degrade_below=0.9))
    eng = StreamingJoinEngine(two_way(), cfg)
    batches = [_zipf_batch(rng, 0) for _ in range(9)]
    for b in batches[:5]:
        eng.ingest(b)
    rep = eng.fail_hosts([0, 1])  # 6/8 < 0.9 -> degrade, capacity clamped
    assert rep.mode == "degrade"
    eng.save_checkpoint(str(tmp_path))
    resumed = StreamingJoinEngine.restore(str(tmp_path), two_way(), cfg)
    assert len(resumed.recoveries) == 1
    assert resumed.recoveries == eng.recoveries
    assert resumed.total_replayed == eng.total_replayed
    assert resumed._hosts.alive == eng._hosts.alive
    assert resumed._controller.capacity_factor == pytest.approx(6 / 8)
    for b in batches[5:]:
        eng.ingest(b)
        resumed.ingest(b)
    assert (resumed.window_count, resumed.window_checksum) == (
        eng.window_count, eng.window_checksum,
    )


def test_pre_recovery_checkpoint_restores_with_recovery_on(tmp_path):
    """A checkpoint written before recovery existed (or with it disabled)
    restores into a recovery-enabled engine: hosts are assigned fresh and
    the engine can immediately survive a loss."""
    rng = np.random.default_rng(10)
    off = _cfg(recovery=RecoveryPolicy())
    eng = StreamingJoinEngine(two_way(), off)
    for _ in range(5):
        eng.ingest(_zipf_batch(rng, 0))
    eng.save_checkpoint(str(tmp_path))
    on = _cfg()
    resumed = StreamingJoinEngine.restore(str(tmp_path), two_way(), on)
    assert resumed._hosts.host_of.size == resumed.plan.total_reducers
    rep = resumed.fail_hosts([0])
    assert rep is not None and rep.verified
