"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import cms_update, flash_attention, flat_join, histogram, reducer_join
from repro.kernels.ref import (
    attention_ref,
    block_join_ref,
    cms_update_ref,
    histogram_ref,
    tiled_join_ref,
)


# ------------------------------------------------------------------ histogram
@pytest.mark.parametrize("n", [1, 7, 256, 1000, 4096])
@pytest.mark.parametrize("num_bins", [4, 64, 513])
def test_histogram_shapes(n, num_bins):
    rng = np.random.default_rng(n * 1000 + num_bins)
    vals = rng.integers(-1, num_bins, size=n).astype(np.int32)  # incl. invalid
    got = histogram(jnp.asarray(vals), num_bins)
    want = histogram_ref(jnp.asarray(vals), num_bins)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block", [16, 128, 1024])
def test_histogram_block_invariance(block):
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 100, size=777).astype(np.int32)
    got = histogram(jnp.asarray(vals), 100, block=block)
    want = np.bincount(vals, minlength=100)
    np.testing.assert_array_equal(np.asarray(got), want)


# ----------------------------------------------------------------- cms update
@pytest.mark.parametrize("n", [1, 100, 777, 4096])
@pytest.mark.parametrize("width", [64, 257, 1024])
def test_cms_update_shapes(n, width):
    rng = np.random.default_rng(n + width)
    vals = rng.integers(0, 1 << 20, size=n).astype(np.int32)
    seeds = (11, 222, 3333)
    got = cms_update(jnp.asarray(vals), seeds, width)
    want = cms_update_ref(jnp.asarray(vals), seeds, width)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # every key lands in exactly one bucket per row
    np.testing.assert_array_equal(np.asarray(got).sum(axis=1), n)


@pytest.mark.parametrize("block", [16, 128, 512])
def test_cms_update_block_invariance(block):
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 10_000, size=1000).astype(np.int32)
    seeds = (5, 55)
    got = cms_update(jnp.asarray(vals), seeds, 128, block=block)
    want = cms_update_ref(jnp.asarray(vals), seeds, 128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_cms_update_matches_host_buckets():
    """Device buckets agree bit-for-bit with the host mix32 family that the
    streaming sketches use (repro.mapreduce.hashing.bucket_np)."""
    from repro.mapreduce.hashing import bucket_np

    rng = np.random.default_rng(1)
    vals = rng.integers(0, 1 << 30, size=513).astype(np.int64)
    seeds = (17, 1717, 171717)
    width = 251
    got = np.asarray(cms_update(jnp.asarray(vals, jnp.int32), seeds, width))
    want = np.stack(
        [np.bincount(bucket_np(vals, s, width), minlength=width) for s in seeds]
    )
    np.testing.assert_array_equal(got, want)


# ----------------------------------------------------------------- block join
@pytest.mark.parametrize("k,cap_r,cap_s,c", [(1, 8, 8, 1), (4, 32, 16, 1), (3, 64, 64, 2), (8, 128, 32, 3)])
def test_reducer_join_sweep(k, cap_r, cap_s, c):
    rng = np.random.default_rng(k * 100 + cap_r + c)
    rk = rng.integers(0, 10, size=(k, cap_r, c)).astype(np.int32)
    sk = rng.integers(0, 10, size=(k, cap_s, c)).astype(np.int32)
    rw = rng.integers(0, 5, size=(k, cap_r)).astype(np.int32)  # 0s = invalid
    sw = rng.integers(0, 5, size=(k, cap_s)).astype(np.int32)
    got_cnt, got_chk = reducer_join(*map(jnp.asarray, (rk, rw, sk, sw)))
    want_cnt, want_chk = block_join_ref(*map(jnp.asarray, (rk, rw, sk, sw)))
    np.testing.assert_array_equal(np.asarray(got_cnt), np.asarray(want_cnt))
    np.testing.assert_array_equal(np.asarray(got_chk), np.asarray(want_chk))


@pytest.mark.parametrize("n,m,bn,bm", [(100, 50, 32, 32), (513, 257, 128, 64), (1, 1, 8, 8)])
def test_flat_join_sweep(n, m, bn, bm):
    rng = np.random.default_rng(n + m)
    rk = rng.integers(0, 20, size=(n, 1)).astype(np.int32)
    sk = rng.integers(0, 20, size=(m, 1)).astype(np.int32)
    rw = rng.integers(1, 7, size=n).astype(np.int32)
    sw = rng.integers(1, 7, size=m).astype(np.int32)
    got_cnt, got_chk = flat_join(
        jnp.asarray(rk), jnp.asarray(rw), jnp.asarray(sk), jnp.asarray(sw),
        block_n=bn, block_m=bm,
    )
    want_cnt, want_chk = tiled_join_ref(
        jnp.asarray(rk), jnp.asarray(rw), jnp.asarray(sk), jnp.asarray(sw)
    )
    assert int(got_cnt) == int(want_cnt)
    assert int(got_chk) == int(want_chk)


def test_flat_join_wraparound_checksum():
    # checksums intentionally wrap mod 2^32 — verify against python ints
    n = 256
    rk = np.zeros((n, 1), np.int32)
    sk = np.zeros((n, 1), np.int32)
    rw = np.full(n, 40_000, np.int32)
    sw = np.full(n, 40_000, np.int32)
    _, chk = flat_join(*map(jnp.asarray, (rk, rw, sk, sw)))
    expect = (40_000 * 40_000 * n * n) % (1 << 32)
    assert int(np.uint32(chk)) == expect


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize(
    "b,h,hkv,l,d,causal",
    [
        (1, 2, 2, 128, 32, True),
        (2, 4, 2, 128, 64, True),
        (1, 8, 1, 256, 32, True),   # MQA
        (2, 2, 2, 128, 32, False),
        (1, 4, 4, 64, 16, True),
    ],
)
def test_flash_attention_sweep(b, h, hkv, l, d, causal):
    rng = np.random.default_rng(b * 100 + h + l)
    q = rng.normal(size=(b, h, l, d)).astype(np.float32)
    k = rng.normal(size=(b, hkv, l, d)).astype(np.float32)
    v = rng.normal(size=(b, hkv, l, d)).astype(np.float32)
    got = flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=causal, block_q=64, block_k=64,
    )
    want = attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), dtype=jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), dtype=jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), dtype=jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32),
        np.asarray(want, dtype=np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_flash_attention_matches_uneven_blocks():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 32)), dtype=jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 32)), dtype=jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 32)), dtype=jnp.float32)
    a = flash_attention(q, k, v, causal=True, block_q=64, block_k=128)
    b = flash_attention(q, k, v, causal=True, block_q=128, block_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


# -------------------------------------------------------------------- wkv6
@pytest.mark.parametrize("bh,l,hd,chunk", [(2, 32, 8, 16), (4, 64, 16, 64), (1, 128, 32, 32)])
def test_wkv6_kernel_matches_ref(bh, l, hd, chunk):
    from repro.kernels.wkv6 import wkv6_pallas, wkv6_ref

    rng = np.random.default_rng(bh * 100 + l)
    r = rng.normal(size=(bh, l, hd)).astype(np.float32)
    k = rng.normal(size=(bh, l, hd)).astype(np.float32) * 0.3
    v = rng.normal(size=(bh, l, hd)).astype(np.float32)
    w = rng.uniform(0.6, 0.999, size=(bh, l, hd)).astype(np.float32)
    u = rng.normal(size=(bh, hd)).astype(np.float32) * 0.1
    got = wkv6_pallas(*map(jnp.asarray, (r, k, v, w, u)), chunk=chunk)
    want = wkv6_ref(*map(jnp.asarray, (r, k, v, w, u)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_wkv6_kernel_state_carries_across_chunks():
    from repro.kernels.wkv6 import wkv6_pallas

    rng = np.random.default_rng(0)
    args = [
        rng.normal(size=(1, 64, 8)).astype(np.float32) for _ in range(3)
    ]
    w = rng.uniform(0.8, 0.99, size=(1, 64, 8)).astype(np.float32)
    u = rng.normal(size=(1, 8)).astype(np.float32)
    one_chunk = wkv6_pallas(*map(jnp.asarray, (args[0], args[1], args[2], w, u)), chunk=64)
    four_chunks = wkv6_pallas(*map(jnp.asarray, (args[0], args[1], args[2], w, u)), chunk=16)
    np.testing.assert_allclose(np.asarray(one_chunk), np.asarray(four_chunks), rtol=1e-5, atol=1e-5)


def test_wkv6_matches_model_scan():
    """Kernel agrees with the model's chunked/unrolled training scan."""
    from repro.kernels.wkv6 import wkv6_pallas
    from repro.models.rwkv6 import _wkv_scan

    rng = np.random.default_rng(1)
    b, l, h, hd = 2, 64, 3, 8
    r, k, v = (rng.normal(size=(b, l, h, hd)).astype(np.float32) for _ in range(3))
    w = rng.uniform(0.7, 0.999, size=(b, l, h, hd)).astype(np.float32)
    u = rng.normal(size=(h, hd)).astype(np.float32) * 0.1
    s0 = np.zeros((b, h, hd, hd), np.float32)
    y_scan, _ = _wkv_scan(*map(jnp.asarray, (r, k, v, w, u, s0)), chunk=16, unroll=4)
    flat = lambda a: jnp.asarray(a.transpose(0, 2, 1, 3).reshape(b * h, l, hd))
    u_flat = jnp.broadcast_to(jnp.asarray(u)[None], (b, h, hd)).reshape(b * h, hd)
    y_kern = wkv6_pallas(flat(r), flat(k), flat(v), flat(w), u_flat, chunk=16)
    y_kern = np.asarray(y_kern).reshape(b, h, l, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y_scan), y_kern, rtol=2e-4, atol=2e-4)


# ------------------------------------------------- chunked jnp attention path
@pytest.mark.parametrize("l,chunk,causal,window", [
    (256, 64, True, None), (256, 128, False, None), (512, 128, True, 64),
])
def test_sdpa_chunked_matches_ref(l, chunk, causal, window):
    """The scan-over-query-blocks path used for 32k prefill lowering must
    agree with dense attention."""
    from repro.models.layers import _sdpa_chunked

    rng = np.random.default_rng(l + chunk)
    b, h, hkv, d = 2, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, h, l, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, l, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, l, d)), jnp.float32)
    eff = None if window is None else jnp.int32(window)
    got = _sdpa_chunked(q, k, v, causal, eff, chunk, None)
    # dense reference with the same mask
    import math as _m
    group = h // hkv
    qg = q.reshape(b, hkv, group, l, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k) / _m.sqrt(d)
    qp, kp = jnp.arange(l)[:, None], jnp.arange(l)[None, :]
    mask = jnp.ones((l, l), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= (qp - kp) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, -1)
    want = jnp.einsum("bhgqk,bhkd->bhgqd", p, v).reshape(b, h, l, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_sdpa_chunked_grad_finite():
    from repro.models.layers import _sdpa_chunked

    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 8)), jnp.float32)
    g = jax.grad(lambda q: _sdpa_chunked(q, k, v, True, None, 64, None).sum())(q)
    assert np.all(np.isfinite(np.asarray(g)))


def test_model_flash_path_matches_default(monkeypatch):
    """REPRO_USE_FLASH=1 routes model attention through the Pallas kernel;
    outputs must match the jnp path."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import build_model

    cfg = dataclasses.replace(
        get_config("olmo-1b").reduced(), max_seq=128
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(21))
    rng = np.random.default_rng(21)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 128)), jnp.int32)}
    base = np.asarray(
        model.forward_hidden(params, batch, dtype=jnp.float32, remat=False),
        np.float32,
    )
    monkeypatch.setenv("REPRO_USE_FLASH", "1")
    flash = np.asarray(
        model.forward_hidden(params, batch, dtype=jnp.float32, remat=False),
        np.float32,
    )
    np.testing.assert_allclose(flash, base, rtol=2e-4, atol=2e-4)
