"""Observability layer tests (DESIGN.md §10): tracer semantics, metrics
determinism, per-tenant series isolation, and SkewScope exactness.

The contracts, in the order the acceptance criteria state them:

  * spans nest and order correctly, and a disabled tracer hands every
    call site the same shared no-op span — zero allocation on the fused
    hot path;
  * ``MetricsRegistry.snapshot()`` is bit-deterministic for counters and
    gauges under seeded streams (wall time lives only in histograms);
  * tenants sharing one registry stay isolated series-wise: a fault in
    tenant B never touches tenant A's series;
  * SkewScope's per-reducer tuple counts equal the distributed shuffle
    oracle's ``reducer_loads`` bit-for-bit on a seeded Zipf batch.
"""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro.core import two_way
from repro.mapreduce.shuffle import run_distributed
from repro.obs import (
    NULL_OBS,
    NULL_SPAN,
    MetricsRegistry,
    Observability,
    ObsPolicy,
    Tracer,
)
from repro.stream import (
    MultiQueryEngine,
    StreamConfig,
    StreamingJoinEngine,
    TenancyPolicy,
    TenantSpec,
)
from repro.testing.faults import FaultInjector, FaultSpec

pytestmark = pytest.mark.obs

ALL_ON = ObsPolicy(trace=True, metrics=True, skewscope=True)


def _zipf_batch(rng, n_r=900, n_s=250, domain=2500, a=1.7, shift=0):
    b_r = ((rng.zipf(a, n_r) - 1) + shift) % domain
    b_s = ((rng.zipf(a, n_s) - 1) + shift) % domain
    r = np.stack([rng.integers(0, domain, n_r), b_r], 1).astype(np.int64)
    s = np.stack([b_s, rng.integers(0, domain, n_s)], 1).astype(np.int64)
    return {"R": r, "S": s}


def _run_engine(n_batches=6, policy=ALL_ON, shift_at=3):
    rng = np.random.default_rng(7)
    eng = StreamingJoinEngine(
        two_way(), StreamConfig(q=100, decay=0.5, load_factor=2.0, obs=policy)
    )
    for i in range(n_batches):
        eng.ingest(_zipf_batch(rng, shift=0 if i < shift_at else 1100, a=1.5))
    return eng


# ---- tracer ----------------------------------------------------------------


def test_span_nesting_and_ordering():
    fake = [0]

    def clock():
        fake[0] += 1000  # 1µs per call, fully deterministic
        return fake[0]

    tr = Tracer(enabled=True, clock_ns=clock)
    tr.set_batch(0)
    with tr.span("outer"):
        assert tr.depth == 1
        with tr.span("inner", args={"k": 1}):
            assert tr.depth == 2
        tr.instant("mark")
    assert tr.depth == 0

    events = tr.to_chrome()["traceEvents"]
    by_name = {e["name"]: e for e in events}
    inner, outer = by_name["inner"], by_name["outer"]
    # completion events are emitted on exit: inner closes before outer
    assert events.index(inner) < events.index(outer)
    # the child interval lies strictly inside the parent's
    assert outer["ts"] < inner["ts"]
    assert inner["ts"] + inner["dur"] < outer["ts"] + outer["dur"]
    assert inner["args"]["k"] == 1
    # span ids are batch-scoped and sequential
    assert outer["args"]["span_id"] == "0.1"
    assert inner["args"]["span_id"] == "0.2"
    assert by_name["mark"]["ph"] == "i"


def test_disabled_tracer_is_allocation_free():
    tr = Tracer(enabled=False)
    # every call site gets the SAME shared no-op span object — nothing is
    # allocated on the hot path when tracing is off
    s1 = tr.span("ingest", args=None)
    s2 = tr.span("route", args=None)
    assert s1 is s2 is NULL_SPAN
    with s1:
        pass
    tr.instant("nothing")
    assert tr.to_chrome()["traceEvents"] == []
    # the NULL_OBS facade rides the same path
    assert NULL_OBS.span("x") is NULL_SPAN


def test_engine_trace_covers_batch_lifecycle(tmp_path):
    eng = _run_engine()
    names = eng.obs.tracer.span_names()
    for expected in (
        "ingest", "sketch.update", "route", "join.delta", "drift.check",
        "retention.expire", "replan", "replan.solve", "replan.migrate",
    ):
        assert expected in names, f"missing span {expected!r}: {names}"
    # every non-root event nests inside some ingest interval
    events = eng.obs.tracer.to_chrome()["traceEvents"]
    roots = [e for e in events if e["name"] == "ingest"]
    for e in events:
        if e["name"] == "ingest" or e["ph"] != "X":
            continue
        assert any(
            r["ts"] <= e["ts"] and e["ts"] + e["dur"] <= r["ts"] + r["dur"]
            for r in roots
        ), f"span {e['name']} is not nested inside an ingest span"
    # the dump is Chrome/Perfetto trace-event JSON
    out = tmp_path / "trace.json"
    eng.obs.tracer.dump(str(out))
    doc = json.loads(out.read_text())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    assert len(doc["traceEvents"]) == len(events)


# ---- metrics ---------------------------------------------------------------


def test_metrics_snapshot_determinism():
    a = _run_engine().obs.metrics.snapshot()
    b = _run_engine().obs.metrics.snapshot()
    # counters and gauges are bit-stable under the seeded stream; wall
    # time lives only in histogram sums, so compare bucket counts too
    assert a["counters"] == b["counters"]
    assert a["gauges"] == b["gauges"]
    assert set(a["histograms"]) == set(b["histograms"])
    for key in a["histograms"]:
        assert a["histograms"][key]["count"] == b["histograms"][key]["count"]
    # the replan trigger is a labeled counter series
    replans = {k: v for k, v in a["counters"].items()
               if k.startswith("stream_replan_total")}
    assert 'stream_replan_total{trigger="initial"}' in replans
    assert sum(replans.values()) >= 2  # initial install + the drift replan


def test_prometheus_dump_is_well_formed():
    reg = MetricsRegistry()
    reg.counter("stream_shed_rows_total", tenant="q1", rel="R").inc(3)
    reg.gauge("stream_hosts_alive").set(7)
    reg.histogram("stream_batch_seconds", buckets=(0.1, 1.0)).observe(0.05)
    text = reg.to_prometheus()
    assert "# TYPE stream_shed_rows_total counter" in text
    assert 'stream_shed_rows_total{rel="R",tenant="q1"} 3' in text
    assert "stream_hosts_alive 7" in text
    assert 'stream_batch_seconds_bucket{le="0.1"} 1' in text
    assert 'stream_batch_seconds_bucket{le="+Inf"} 1' in text
    assert "stream_batch_seconds_count 1" in text


def test_disabled_registry_returns_null_instruments():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("anything", tenant="x")
    assert c is reg.gauge("other") is reg.histogram("third")
    c.inc(5)
    assert reg.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


# ---- per-tenant isolation --------------------------------------------------


def test_tenant_label_isolation():
    query = two_way()
    cfg = StreamConfig(q=100, decay=0.5, load_factor=2.0)
    mq = MultiQueryEngine(
        [TenantSpec(f"q{i}", query, cfg) for i in range(2)],
        TenancyPolicy(obs=ObsPolicy(metrics=True)),
    )
    inj = FaultInjector(
        [FaultSpec(kind="poison_rows", target="tenant", tenant="q1",
                   batch=2, poison="nan")]
    )
    mq.arm_faults(inj)
    rng = np.random.default_rng(11)
    for _ in range(5):
        mq.ingest(_zipf_batch(rng))
    inj.assert_all_resolved()

    counters = mq.obs.metrics.snapshot()["counters"]
    # the poison pill tripped q1's breaker — and ONLY q1's series
    trips = {k: v for k, v in counters.items()
             if k.startswith("tenancy_breaker_transitions_total")}
    assert trips, "breaker transition was not recorded"
    assert all('tenant="q1"' in k for k in trips), trips
    # q0's per-tenant series are untouched by its neighbor's fault: it
    # ingested every batch, q1 skipped its quarantine window
    assert counters['stream_batches_total{tenant="q0"}'] == 5
    assert counters['stream_batches_total{tenant="q1"}'] < 5


# ---- skewscope -------------------------------------------------------------


def test_skewscope_matches_distributed_oracle():
    """Per-reducer tuple counts == the shuffle oracle's reducer_loads,
    bit-for-bit, on a seeded Zipf batch (the acceptance contract)."""
    rng = np.random.default_rng(3)
    batch = _zipf_batch(rng, n_r=1200, n_s=300, a=1.6)
    query = two_way()
    eng = StreamingJoinEngine(
        query,
        StreamConfig(q=100, decay=0.5, load_factor=2.0,
                     obs=ObsPolicy(skewscope=True)),
    )
    eng.ingest(batch)

    # generous caps: the contract needs a lossless oracle shuffle
    res = run_distributed(query, batch, eng.plan,
                          cap_factor=12.0, route_cap_factor=12.0)
    assert res.overflow == 0, "oracle shuffle overflowed — raise caps"

    skew = eng.obs.skew
    got = skew.tuples_per_reducer()
    want = np.asarray(res.reducer_loads, dtype=np.int64)
    assert got.shape == want.shape
    np.testing.assert_array_equal(got, want)
    # and the engine's own load accounting agrees with both
    np.testing.assert_array_equal(np.asarray(eng._loads, dtype=np.int64), got)

    snap = eng.skew_report()
    assert snap.total_tuples == int(want.sum())
    assert snap.max_tuples == int(want.max())
    assert snap.imbalance == pytest.approx(want.max() / want.mean())
    assert 0.0 <= snap.hh_hit_rate <= 1.0
    # the retained window is the whole stream here: the decayed CMS
    # estimate is exact on every audited heavy hitter
    for err in snap.cms_error.values():
        assert err == pytest.approx(0.0, abs=1e-9)


def test_skew_report_surfaces_in_batch_report():
    eng = _run_engine(n_batches=4)
    rep = eng.reports[-1]
    assert rep.obs is not None
    assert rep.obs["skew"]["total_reducers"] == eng.plan.total_reducers
    assert rep.obs["metrics"]["counters"]["stream_batches_total"] == 4
    # drift decision surfaces trigger + observed/threshold on the report
    replanned = [r for r in eng.reports if r.replanned and r.batch > 0]
    for r in replanned:
        assert r.drift_trigger in {"overload", "comm", "faded_pin"}
        assert r.drift_observed > r.drift_threshold > 0.0
