"""Unit tests: share solver vs the paper's closed forms (§1.1, §3, §8)."""
import math

import numpy as np
import pytest

from repro.core import (
    chain_cost,
    chain_cost_equal_sizes,
    chain_join,
    chain_shares,
    cycle_join,
    dominated_attributes,
    make_query,
    share_attributes,
    solve_k_for_capacity,
    solve_shares,
    subchain_budgets,
    symmetric_cost,
    symmetric_cost_equal_sizes,
    symmetric_join,
    three_chain_cost,
    three_way_paper,
    triangle,
    triangle_cost,
    triangle_shares,
    two_way,
    two_way_naive_cost,
    two_way_skew_cost,
    two_way_skew_shares,
)


# ---------------------------------------------------------------- dominance
def test_dominance_three_chain():
    # R(A,B) ⋈ S(B,C) ⋈ T(C,D): A dominated by B, D dominated by C (Ex. 3)
    q = make_query({"R": ("A", "B"), "S": ("B", "C"), "T": ("C", "D")})
    dom = dominated_attributes(q)
    assert dom == {"A", "D"}
    assert share_attributes(q) == ("B", "C")


def test_dominance_two_way():
    # R(A,B) ⋈ S(B,C): A and C dominated by B
    q = two_way()
    assert dominated_attributes(q) == {"A", "C"}
    assert share_attributes(q) == ("B",)


def test_dominance_paper_example8():
    # J = R(A,B) ⋈ S(B,E,C) ⋈ T(C,D) (Ex. 8 case 1): A dom by B, D dom by C,
    # E dom by B (and C).  Share attrs: B, C.
    q = three_way_paper()
    assert share_attributes(q) == ("B", "C")


def test_dominance_with_pinned_hh():
    # Ex. 8 case 2: B pinned (share 1) -> D and E dominated by C; A survives.
    q = three_way_paper()
    attrs = share_attributes(q, fixed_to_one={"B"})
    assert set(attrs) == {"A", "C"}
    # Ex. 8 case 4: C pinned -> A and E dominated by B; D survives.
    attrs = share_attributes(q, fixed_to_one={"C"})
    assert set(attrs) == {"B", "D"}
    # Ex. 8 case 5: B and C pinned -> A, D, E all survive (nothing dominates).
    attrs = share_attributes(q, fixed_to_one={"B", "C"})
    assert set(attrs) == {"A", "D", "E"}


def test_dominance_tie_break():
    # R(A,B) ⋈ S(A,B): A and B occur in identical relation sets; exactly one
    # survives (the first-declared).
    q = make_query({"R": ("A", "B"), "S": ("A", "B")})
    assert share_attributes(q) == ("A",)


# ----------------------------------------------------------- 2-way closed form
@pytest.mark.parametrize("r,s,k", [(1e6, 1e5, 64), (1e5, 1e5, 16), (5e4, 2e6, 256)])
def test_two_way_skew_matches_solver(r, s, k):
    # HH residual of R(A,B) ⋈ S(B,C) with B pinned: minimize ry + sx, xy = k
    q = two_way()
    sol = solve_shares(q, {"R": r, "S": s}, k, fixed_to_one={"B"})
    assert sol.cost == pytest.approx(two_way_skew_cost(r, s, k), rel=1e-4)
    x, y = two_way_skew_shares(r, s, k)
    assert sol.shares["A"] == pytest.approx(x, rel=1e-3)
    assert sol.shares["C"] == pytest.approx(y, rel=1e-3)


def test_two_way_beats_naive():
    r, s, k = 1e6, 1e5, 64
    assert two_way_skew_cost(r, s, k) < two_way_naive_cost(r, s, k)


# ------------------------------------------------------------ triangle (§3)
def test_triangle_matches_solver():
    r1, r2, r3, k = 1e5, 2e5, 1.5e5, 64
    sol = solve_shares(triangle(), {"R1": r1, "R2": r2, "R3": r3}, k)
    assert sol.cost == pytest.approx(triangle_cost(r1, r2, r3, k), rel=1e-4)
    x1, x2, x3 = triangle_shares(r1, r2, r3, k)
    assert sol.shares["X1"] == pytest.approx(x1, rel=1e-3)
    assert sol.shares["X2"] == pytest.approx(x2, rel=1e-3)
    assert sol.shares["X3"] == pytest.approx(x3, rel=1e-3)


# --------------------------------------------------- 3-chain closed form (Ex 3)
def test_three_chain_matches_solver():
    r, s, t, k = 4e5, 1e5, 2e5, 100
    q = make_query({"R": ("A", "B"), "S": ("B", "C"), "T": ("C", "D")})
    sol = solve_shares(q, {"R": r, "S": s, "T": t}, k)
    assert sol.cost == pytest.approx(three_chain_cost(r, s, t, k), rel=1e-4)


# --------------------------------------------------------- chains (§8.1-8.2)
@pytest.mark.parametrize("n,k", [(4, 256), (6, 4096)])
def test_chain_equal_sizes_matches_solver(n, k):
    r = 1e5
    q = chain_join(n)
    sizes = {f"R{i+1}": r for i in range(n)}
    sol = solve_shares(q, sizes, k)
    assert sol.cost == pytest.approx(chain_cost_equal_sizes(n, r, k), rel=1e-3)


def test_chain_arbitrary_sizes_matches_solver():
    sizes_list = [2e5, 1e5, 3e5, 1.5e5]
    k = 4096.0
    q = chain_join(4)
    sizes = {f"R{i+1}": s for i, s in enumerate(sizes_list)}
    sol = solve_shares(q, sizes, k)
    assert sol.cost == pytest.approx(chain_cost(sizes_list, k), rel=1e-3)
    shares = chain_shares(sizes_list, k)
    assert math.prod(shares) == pytest.approx(k, rel=1e-6)
    for a, expect in zip(("A1", "A2", "A3"), shares):
        assert sol.shares[a] == pytest.approx(expect, rel=1e-2)


def test_subchain_budgets_balance():
    # paper §8.1 Lagrangean balance: (n_i-2) k_i^{(n_i-2)/n_i} equal over i
    ns, k = [4, 6], 1 << 16
    ks = subchain_budgets(ns, k)
    assert math.prod(ks) == pytest.approx(k, rel=1e-6)
    vals = [(n - 2) * kk ** ((n - 2) / n) / n for n, kk in zip(ns, ks)]
    # with C_i = n_i the balance includes the coefficient: C_i alpha_i k^alpha
    bal = [n * ((n - 2) / n) * kk ** ((n - 2) / n) for n, kk in zip(ns, ks)]
    assert bal[0] == pytest.approx(bal[1], rel=1e-3)


def test_subchain_degenerate_gets_one():
    ks = subchain_budgets([2, 4], 256)
    assert ks[0] == pytest.approx(1.0)
    assert ks[1] == pytest.approx(256.0)


# ------------------------------------------------------- symmetric joins (§8.3)
@pytest.mark.parametrize("n,d,k", [(3, 2, 64), (4, 2, 256), (5, 3, 1024), (6, 4, 4096)])
def test_symmetric_equal_sizes_matches_solver(n, d, k):
    r = 1e5
    q = symmetric_join(n, d)
    sizes = {f"R{j+1}": r for j in range(n)}
    sol = solve_shares(q, sizes, k)
    assert sol.cost == pytest.approx(symmetric_cost_equal_sizes(n, d, r, k), rel=1e-3)
    assert sol.cost == pytest.approx(symmetric_cost(n, d, [r] * n, k), rel=1e-3)


def test_symmetric_arbitrary_sizes_matches_solver():
    n, d, k = 4, 2, 256.0
    sizes_list = [1e5, 1.5e5, 1e5, 1.5e5]  # balanced enough for interior optimum
    q = symmetric_join(n, d)
    sizes = {f"R{j+1}": s for j, s in enumerate(sizes_list)}
    sol = solve_shares(q, sizes, k)
    assert sol.cost == pytest.approx(symmetric_cost(n, d, sizes_list, k), rel=1e-3)


def test_symmetric_beats_chain_scaling():
    # §8.3 discussion: symmetric cost ∝ k^{1-d/n} decreases relative to chain
    # cost ∝ k^{(n-2)/n} as d -> n.
    n, r, k = 6, 1e5, 4096
    assert symmetric_cost_equal_sizes(n, 5, r, k) < symmetric_cost_equal_sizes(n, 2, r, k)
    assert symmetric_cost_equal_sizes(n, n - 1, r, k) < chain_cost_equal_sizes(n, r, k)


# ----------------------------------------------------------- capacity rule (§4)
def test_capacity_rule_two_way():
    q = two_way()
    sizes = {"R": 1e6, "S": 1e5}
    qcap = 5e4
    k, sol = solve_k_for_capacity(q, sizes, qcap, fixed_to_one={"B"})
    assert sol.cost / k <= qcap
    # minimality: k-1 must violate
    if k > 1:
        sol2 = solve_shares(q, sizes, k - 1, fixed_to_one={"B"})
        assert sol2.cost / (k - 1) > qcap


def test_capacity_fits_single_reducer():
    q = two_way()
    k, sol = solve_k_for_capacity(q, {"R": 10, "S": 10}, 1000)
    assert k == 1


# ------------------------------------------------------ integer rounding sanity
def test_integer_shares_product_within_budget():
    q = triangle()
    sol = solve_shares(q, {"R1": 1e5, "R2": 3e5, "R3": 2e5}, 60)
    prod = math.prod(sol.int_shares.values())
    assert prod <= 60
    assert sol.int_cost >= sol.cost * 0.5  # sane, not wildly off
