"""Streaming subsystem tests: sketches, drift monitor, micro-batch engine.

The load-bearing invariant: after any prefix of micro-batches — through
heavy-hitter drift, replans, and state migration — the engine's cumulative
(count, checksum) equals the batch pipeline on the concatenated input.
"""
import numpy as np
import pytest

from repro.core import plan_with_hh, three_way_paper, two_way
from repro.core.heavy_hitters import CountMinSketch, exact_heavy_hitters
from repro.data import paper_2way, paper_3way
from repro.mapreduce import oracle_join, run_join
from repro.stream import (
    DecayingCountMin,
    DriftMonitor,
    SpaceSaving,
    StreamConfig,
    StreamHHTracker,
    StreamingJoinEngine,
)


def _zipf_batch(rng, shift, n_r=1200, n_s=300, domain=3000, a=1.6):
    """2-way batch whose Zipf-heavy B values sit at ``shift`` (mod domain)."""
    b_r = ((rng.zipf(a, n_r) - 1) + shift) % domain
    b_s = ((rng.zipf(a, n_s) - 1) + shift) % domain
    r = np.stack([rng.integers(0, domain, n_r), b_r], 1).astype(np.int64)
    s = np.stack([b_s, rng.integers(0, domain, n_s)], 1).astype(np.int64)
    return {"R": r, "S": s}


# --------------------------------------------------------- CountMinSketch
def test_cms_merge_associative():
    rng = np.random.default_rng(0)
    keys = [rng.integers(0, 10_000, size=2_000) for _ in range(3)]
    sketches = []
    for k in keys:
        s = CountMinSketch(width=512, depth=4, seed=7)
        s.update(k)
        sketches.append(s)
    a, b, c = sketches
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    np.testing.assert_array_equal(left.table, right.table)
    assert left.total == right.total == sum(k.size for k in keys)
    # merged == single sketch over the concatenation
    whole = CountMinSketch(width=512, depth=4, seed=7)
    whole.update(np.concatenate(keys))
    np.testing.assert_array_equal(left.table, whole.table)


def test_cms_merge_rejects_mismatched_seeds():
    a = CountMinSketch(width=64, depth=3, seed=0)
    b = CountMinSketch(width=64, depth=3, seed=1)
    with pytest.raises(ValueError):
        a.merge(b)


def test_cms_overcount_bound():
    """Estimates never undercount, and err <= eps*N with prob >= 1-delta.

    width/depth from ``from_error``; failure probability per query is
    delta = exp(-depth), so over m queries expect <= m*delta violations —
    with the seeds fixed here there are none.
    """
    eps, delta = 0.01, 0.01
    cms = CountMinSketch.from_error(eps, delta, seed=3)
    assert cms.width >= int(np.e / eps)
    rng = np.random.default_rng(4)
    keys = (rng.zipf(1.4, size=50_000) - 1) % 5_000
    cms.update(keys)
    vals, counts = np.unique(keys, return_counts=True)
    est = cms.estimate(vals)
    assert np.all(est >= counts), "count-min must never undercount"
    violations = np.sum(est - counts > eps * keys.size)
    assert violations <= max(1, int(delta * vals.size))


def test_cms_heavy_hitters_agree_with_exact_on_zipf():
    rng = np.random.default_rng(5)
    col = (rng.zipf(1.5, size=30_000) - 1) % 10_000
    threshold = 300
    exact_vals, _ = exact_heavy_hitters(col, threshold)
    cms = CountMinSketch(width=8192, depth=5, seed=1)
    cms.update(col)
    got_vals, got_counts = cms.heavy_hitters(np.unique(col), threshold)
    # CMS overcounts, so its HH set is a superset of the exact set...
    assert set(exact_vals.tolist()) <= set(got_vals.tolist())
    # ...and with a wide sketch the sets coincide
    assert set(got_vals.tolist()) == set(exact_vals.tolist())
    # estimated counts upper-bound the true ones
    true = {v: c for v, c in zip(*np.unique(col, return_counts=True))}
    for v, c in zip(got_vals.tolist(), got_counts.tolist()):
        assert c >= true[v]


# ------------------------------------------------------- decaying sketches
def test_decaying_cms_matches_kernel_and_forgets():
    rng = np.random.default_rng(6)
    cms = DecayingCountMin(width=256, depth=4, seed=2, decay=0.5)
    batch1 = rng.integers(0, 1000, size=500)
    cms.step()
    cms.update(batch1)
    est1 = float(cms.estimate(np.array([batch1[0]]))[0])
    assert est1 >= 1
    # ten empty batches: counts decay toward zero
    for _ in range(10):
        cms.step()
    est2 = float(cms.estimate(np.array([batch1[0]]))[0])
    assert est2 <= est1 / 500


def test_decaying_cms_absorb_matches_update():
    import jax.numpy as jnp

    from repro.kernels import cms_update

    rng = np.random.default_rng(7)
    keys = rng.integers(0, 5000, size=1111).astype(np.int64)
    host = DecayingCountMin(width=512, depth=3, seed=9, decay=1.0)
    host.update(keys)
    dev = DecayingCountMin(width=512, depth=3, seed=9, decay=1.0)
    delta = np.asarray(cms_update(jnp.asarray(keys, jnp.int32), dev.seeds, dev.width))
    dev.absorb(delta.astype(np.float64), keys.size)
    np.testing.assert_array_equal(host.table, dev.table)


def test_space_saving_retains_heavy_values():
    rng = np.random.default_rng(8)
    stream = (rng.zipf(1.3, size=20_000) - 1) % 2_000
    ss = SpaceSaving(capacity=32)
    ss.update(stream)
    vals, counts = np.unique(stream, return_counts=True)
    guaranteed = vals[counts > stream.size / 32]
    got, est = ss.candidates()
    assert set(guaranteed.tolist()) <= set(got.tolist())
    true = dict(zip(vals.tolist(), counts.tolist()))
    for v, c in zip(got.tolist(), est.tolist()):
        assert c >= true.get(v, 0)  # overestimates only


def test_tracker_follows_drift():
    rng = np.random.default_rng(9)
    tracker = StreamHHTracker(two_way(), decay=0.5, seed=0)
    for _ in range(4):
        tracker.observe(_zipf_batch(rng, 0))
    hh0 = set(tracker.hh_values(threshold=100).get("B", ()).tolist())
    assert 0 in hh0  # zipf mode at shift 0
    for _ in range(4):
        tracker.observe(_zipf_batch(rng, 1000))
    hh1 = tracker.hh_values(threshold=100)["B"].tolist()
    assert 1000 in hh1  # the new mode took over
    assert 1000 == hh1[0]  # and leads by rate


# ------------------------------------------------------------ drift monitor
def test_drift_monitor_fires_on_unpinned_heavy_value():
    rng = np.random.default_rng(10)
    batch0 = _zipf_batch(rng, 0)
    tracker = StreamHHTracker(two_way(), decay=0.5)
    tracker.observe(batch0)
    snap = tracker.snapshot(threshold=100)
    plan = plan_with_hh(two_way(), batch0, 120, {a: s.values for a, s in snap.items()})
    mon = DriftMonitor(q=120, load_factor=2.0, cooldown=0)
    mon.install(plan, two_way(), batch0)
    # same distribution: no drift
    batch1 = _zipf_batch(rng, 0)
    tracker.observe(batch1)
    d = mon.check(plan, two_way(), batch1, tracker.snapshot(threshold=100))
    assert not d.replan
    # shifted distribution: the new mode is unpinned -> overload predicted
    for _ in range(3):
        shifted = _zipf_batch(rng, 1500)
        tracker.observe(shifted)
    d = mon.check(plan, two_way(), shifted, tracker.snapshot(threshold=100))
    assert d.replan and "overload" in d.reason


def test_drift_monitor_fires_on_faded_pin():
    """A pinned HH whose live rate collapsed triggers wasted-replication
    drift even though neither overload nor comm-increase fires."""
    rng = np.random.default_rng(17)
    q = two_way()
    eng = StreamingJoinEngine(q, StreamConfig(q=120, decay=0.5, load_factor=3.0))
    for _ in range(2):
        eng.ingest(_zipf_batch(rng, 0, a=1.8))  # pins the zipf mode
    assert eng.plan.hh_values  # something got pinned
    uniform = lambda: {
        "R": rng.integers(0, 3000, (1200, 2)).astype(np.int64),
        "S": rng.integers(0, 3000, (300, 2)).astype(np.int64),
    }
    for _ in range(4):  # skew vanishes entirely
        eng.ingest(uniform())
    assert any("faded pin" in r.drift_reason for r in eng.reports if r.replanned)
    count, checksum, _, _ = oracle_join(q, eng.history_data())
    assert (eng.total_count, eng.total_checksum) == (count, checksum)


def test_plan_with_hh_trims_rich_hh_set_instead_of_raising():
    from repro.core import make_query

    query = make_query(
        {"R": ("A", "B"), "S": ("B", "C"), "T": ("C", "D"), "U": ("D", "A")}
    )
    rng = np.random.default_rng(18)
    data = {
        r.name: rng.integers(0, 100, (200, 2)).astype(np.int64)
        for r in query.relations
    }
    hh = {a: np.arange(8, dtype=np.int64) for a in ("A", "B", "C", "D")}
    plan = plan_with_hh(query, data, q=100, hh_values=hh)  # 9^4 combos untrimmed
    assert 0 < len(plan.residuals) <= 1024


# ---------------------------------------------------------------- engine
def test_engine_matches_oracle_static_stream():
    rng = np.random.default_rng(11)
    q = two_way()
    eng = StreamingJoinEngine(q, StreamConfig(q=150))
    for _ in range(4):
        rep = eng.ingest(paper_2way(rng, n_r=800, n_s=200, domain=1200))
        # prefix invariant: cumulative totals match the concatenated input
        count, checksum, _, _ = oracle_join(q, eng.history_data())
        assert (rep.total_count, rep.total_checksum) == (count, checksum)
    assert eng.replan_count == 0


def test_engine_3way_matches_batch_run_join():
    rng = np.random.default_rng(12)
    q3 = three_way_paper()
    eng = StreamingJoinEngine(q3, StreamConfig(q=100, hh_threshold=30))
    for _ in range(3):
        eng.ingest(paper_3way(rng, n=250, domain=250))
    cat = eng.history_data()
    from repro.core import plan_shares_skew

    plan = plan_shares_skew(q3, cat, q=300)
    res = run_join(q3, cat, plan, cap_factor=4.0)
    assert res.overflow == 0
    assert (eng.total_count, eng.total_checksum) == (res.count, res.checksum)


def test_engine_drift_replan_and_correctness():
    """Zipf exponent (2.0 -> 1.4) + location shift mid-run: >=1 drift replan
    fires and the cumulative fingerprint matches the concatenated oracle."""
    rng = np.random.default_rng(13)
    q = two_way()
    eng = StreamingJoinEngine(q, StreamConfig(q=120, decay=0.5, load_factor=2.0))
    for _ in range(3):
        eng.ingest(_zipf_batch(rng, 0, n_r=900, n_s=220, domain=2000, a=2.0))
    for _ in range(3):
        eng.ingest(_zipf_batch(rng, 700, n_r=900, n_s=220, domain=2000, a=1.4))
    assert eng.replan_count >= 1
    assert any("overload" in r.drift_reason for r in eng.reports if r.replanned)
    count, checksum, _, _ = oracle_join(q, eng.history_data())
    assert (eng.total_count, eng.total_checksum) == (count, checksum)


def test_engine_comm_within_factor_of_exact_replan_oracle():
    """Cumulative new-tuple shuffle volume stays within 1.25x of an oracle
    that replans every batch from exact heavy hitters."""
    from repro.core import plan_shares_skew
    from repro.mapreduce import predicted_comm

    rng = np.random.default_rng(14)
    q = two_way()
    eng = StreamingJoinEngine(q, StreamConfig(q=120, decay=0.5, load_factor=2.0))
    oracle_comm = 0
    batches = [_zipf_batch(rng, 0, a=2.0) for _ in range(3)] + [
        _zipf_batch(rng, 1000, a=1.4) for _ in range(3)
    ]
    for b in batches:
        eng.ingest(b)
        oracle_plan = plan_shares_skew(q, b, q=120)
        oracle_comm += sum(predicted_comm(oracle_plan).values())
    assert eng.replan_count >= 1
    assert eng.cumulative_comm <= 1.25 * oracle_comm, (
        eng.cumulative_comm,
        oracle_comm,
    )


def test_engine_empty_and_lopsided_batches():
    rng = np.random.default_rng(15)
    q = two_way()
    eng = StreamingJoinEngine(q, StreamConfig(q=100))
    eng.ingest(
        {
            "R": np.zeros((0, 2), dtype=np.int64),
            "S": rng.integers(0, 100, (50, 2)).astype(np.int64),
        }
    )
    assert eng.total_count == 0
    eng.ingest(
        {
            "R": rng.integers(0, 100, (80, 2)).astype(np.int64),
            "S": np.zeros((0, 2), dtype=np.int64),
        }
    )
    # R tuples must join with the PREVIOUS batch's S tuples
    count, checksum, _, _ = oracle_join(q, eng.history_data())
    assert (eng.total_count, eng.total_checksum) == (count, checksum)
    assert count > 0


def test_engine_recovers_from_empty_first_batch():
    """A plan installed against an empty first batch (1-reducer degenerate
    grid, zero comm baseline) must be replaced once real traffic arrives —
    the comm-drift trigger fires even with a zero baseline."""
    rng = np.random.default_rng(19)
    q = two_way()
    eng = StreamingJoinEngine(q, StreamConfig(q=100, cooldown=0))
    empty = {
        "R": np.zeros((0, 2), dtype=np.int64),
        "S": np.zeros((0, 2), dtype=np.int64),
    }
    eng.ingest(empty)
    assert eng.plan.total_reducers == 1  # degenerate plan, nothing to size for
    for _ in range(3):
        eng.ingest(
            {
                "R": rng.integers(0, 2000, (600, 2)).astype(np.int64),
                "S": rng.integers(0, 2000, (150, 2)).astype(np.int64),
            }
        )
    assert any("comm" in r.drift_reason for r in eng.reports if r.replanned)
    assert eng.plan.total_reducers > 1
    count, checksum, _, _ = oracle_join(q, eng.history_data())
    assert (eng.total_count, eng.total_checksum) == (count, checksum)


def test_engine_distributed_recompute_agrees():
    rng = np.random.default_rng(16)
    q = two_way()
    eng = StreamingJoinEngine(q, StreamConfig(q=150))
    for _ in range(2):
        eng.ingest(paper_2way(rng, n_r=500, n_s=150, domain=900))
    res = eng.recompute_distributed(cap_factor=8.0, route_cap_factor=8.0)
    assert res.overflow == 0
    assert (res.count, res.checksum) == (eng.total_count, eng.total_checksum)
