"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU asserting output shapes and finiteness, plus a decode step where the
arch has one (brief deliverable (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, get_config, supported_shapes
from repro.models import build_model, make_batch

ARCH_NAMES = sorted(all_configs())


def test_ten_archs_registered():
    assert len(ARCH_NAMES) == 10


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_and_train_step(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    batch = make_batch(cfg, rng, batch=2, seq=32)

    loss = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), f"{name}: loss not finite"

    # one SGD step: loss must stay finite and params must change
    grads = jax.jit(jax.grad(lambda p: model.loss_fn(p, batch)))(params)
    flat, _ = jax.tree.flatten(grads)
    assert all(np.all(np.isfinite(np.asarray(g, dtype=np.float32))) for g in flat)
    new_params = jax.tree.map(lambda p, g: p - 0.01 * g, params, grads)
    loss2 = jax.jit(model.loss_fn)(new_params, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_hidden_shapes(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(1)
    params = model.init_params(jax.random.PRNGKey(1))
    batch = make_batch(cfg, rng, batch=2, seq=16)
    out = model.forward_hidden(params, batch)
    h = out[0] if isinstance(out, tuple) else out
    expect_len = 16
    if cfg.family == "vlm":
        expect_len += batch["prefix_embeds"].shape[1]
    assert h.shape[0] == 2 and h.shape[-1] == cfg.d_model
    assert h.shape[1] == expect_len
    assert np.all(np.isfinite(np.asarray(h, dtype=np.float32)))


@pytest.mark.parametrize(
    "name", [n for n in ARCH_NAMES if get_config(n).has_decoder]
)
def test_smoke_decode_step(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(2)
    params = model.init_params(jax.random.PRNGKey(2))
    cache = model.init_cache(2, 64)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, 1)), jnp.int32)
    logits, cache = model.decode_step(params, cache, tokens, jnp.int32(0))
    assert logits.shape == (2, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    # second step advances
    logits2, _ = model.decode_step(params, cache, tokens, jnp.int32(1))
    assert np.all(np.isfinite(np.asarray(logits2)))


def test_supported_shapes_rules():
    assert supported_shapes(get_config("rwkv6-3b")) == [
        "train_4k", "prefill_32k", "decode_32k", "long_500k",
    ]
    assert supported_shapes(get_config("zamba2-2.7b"))[-1] == "long_500k"
    assert "long_500k" in supported_shapes(get_config("gemma3-4b"))  # 5:1 local
    assert supported_shapes(get_config("hubert-xlarge")) == ["train_4k", "prefill_32k"]
    assert "long_500k" not in supported_shapes(get_config("command-r-plus-104b"))


def test_param_count_sanity():
    # configs' approximate parameter counts should be in the right ballpark
    assert 90e9 < get_config("command-r-plus-104b").n_params() < 120e9
    assert 0.8e9 < get_config("olmo-1b").n_params() < 1.6e9
    assert 25e9 < get_config("qwen3-moe-30b-a3b").n_params() < 36e9
    assert 2e9 < get_config("qwen3-moe-30b-a3b").n_active_params() < 5e9
