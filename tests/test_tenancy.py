"""Multi-tenant isolation tests (DESIGN.md §9): shared sketch ingest,
blast-radius containment, fair-share overload control, tenant-scoped
recovery, and namespaced checkpoints.

The acceptance proof (`test_isolation_proof`): three concurrent queries
over one shared stream, each hit by a different tenant-targeted fault —
poison rows into A, a forced ``RecoveryExhaustedError`` in B, an overload
burst shed off C — plus a clean bystander D whose cumulative fingerprint
must stay bit-identical to a single-tenant run, with the shared sketch
pass computed exactly once per relation batch (counter-asserted).
"""
import numpy as np
import pytest

from repro.core import two_way
from repro.mapreduce import oracle_join
from repro.stream import (
    DEGRADED,
    FAILED,
    QUARANTINED,
    RUNNING,
    MultiQueryEngine,
    RecoveryPolicy,
    StreamConfig,
    StreamingJoinEngine,
    TenancyPolicy,
    TenantSpec,
)
from repro.stream.sketch import cms_delta
from repro.testing.faults import FaultInjector, FaultSpec

pytestmark = pytest.mark.tenancy

N_BATCHES = 8


def _zipf_batch(rng, shift, n_r=240, n_s=80, domain=600, a=1.6):
    b_r = ((rng.zipf(a, n_r) - 1) + shift) % domain
    b_s = ((rng.zipf(a, n_s) - 1) + shift) % domain
    r = np.stack([rng.integers(0, domain, n_r), b_r], 1).astype(np.int64)
    s = np.stack([b_s, rng.integers(0, domain, n_s)], 1).astype(np.int64)
    return {"R": r, "S": s}


def _batches(n=N_BATCHES, seed=0):
    rng = np.random.default_rng(seed)
    return [_zipf_batch(rng, 0 if i < n // 2 else 300) for i in range(n)]


def _cfg(**kw):
    return StreamConfig(q=60, decay=0.5, load_factor=2.0, **kw)


def _solo_run(config=None, batches=None):
    """Single-tenant reference: the bit-identity baseline."""
    eng = StreamingJoinEngine(two_way(), config or _cfg())
    for b in batches or _batches():
        eng.ingest({k: v.copy() for k, v in b.items()})
    return eng


# ---------------------------------------------------- shared sketch ingest
def test_shared_sketch_runs_once_and_absorbs_bit_identically():
    """N tenants behind one ingest: the CMS pass runs once per relation
    batch, every tenant absorbs it, and every tenant's reports are
    bit-identical to a solo engine — sharing is pure plumbing."""
    batches = _batches()
    solo = _solo_run(batches=batches)
    mq = MultiQueryEngine(
        [TenantSpec(f"t{i}", two_way(), _cfg()) for i in range(3)]
    )
    for b in batches:
        mq.ingest(b)
    for i in range(3):
        eng = mq.engine(f"t{i}")
        assert eng.sketch_ingest_calls == 0  # never computed privately
        for rs, rm in zip(solo.reports, eng.reports):
            assert rs == rm
    # one pass per (attr, rel) column per batch: B appears in R and S
    assert mq.shared_sketch_passes == 2 * N_BATCHES
    assert solo.sketch_ingest_calls == N_BATCHES


def test_cms_delta_matches_private_update():
    """The shared-pass primitive is bit-identical to a private CMS pass
    (integer bincounts are exact in float64)."""
    from repro.stream.sketch import DecayingCountMin

    rng = np.random.default_rng(3)
    col = rng.integers(0, 10_000, 5_000)
    shared = DecayingCountMin(width=256, depth=3, seed=9)
    private = DecayingCountMin(width=256, depth=3, seed=9)
    private.update(col)
    delta = cms_delta(col, private.seeds, private.width)
    shared.absorb(delta, len(col))
    assert np.array_equal(shared.table, private.table)


def test_tampered_tenant_falls_back_to_private_pass():
    """A tenant whose view was tampered (overload burst) must not absorb
    the shared delta for that batch — correctness never rides on it."""
    batches = _batches()
    mq = MultiQueryEngine(
        [TenantSpec("a", two_way(), _cfg()),
         TenantSpec("b", two_way(), _cfg())]
    )
    inj = FaultInjector(
        [FaultSpec(kind="tenant_overload", target="tenant", tenant="b",
                   batch=3, rel="R", rows=500)]
    )
    mq.arm_faults(inj)
    for b in batches:
        mq.ingest(b)
    inj.assert_all_resolved()
    assert mq.engine("a").sketch_ingest_calls == 0
    assert mq.engine("b").sketch_ingest_calls == 1  # the burst batch only


# ---------------------------------------------------- the acceptance proof
def test_isolation_proof():
    """Three faulted queries + one clean bystander over one stream:

      * poison rows -> A (quarantined, reopened, neighbors untouched)
      * forced RecoveryExhaustedError -> B (FAILED, contained)
      * overload burst -> C (shed off C alone)

    The bystander D and every pre-fault prefix stay bit-identical to the
    single-tenant run; the shared sketch ran once per relation batch."""
    batches = _batches()
    solo = _solo_run(batches=batches)
    count, checksum = solo.total_count, solo.total_checksum

    # B runs with the host model on, provisioned so that ANY host loss is
    # beyond the survivable grid (min_hosts == n_hosts)
    mq = MultiQueryEngine(
        [
            TenantSpec("A", two_way(), _cfg()),
            TenantSpec("B", two_way(), _cfg(
                recovery=RecoveryPolicy(n_hosts=4, min_hosts=4))),
            TenantSpec("C", two_way(), _cfg()),
            TenantSpec("D", two_way(), _cfg()),
        ],
        TenancyPolicy(breaker_backoff=1),
    )
    shed_batch = 5
    inj = FaultInjector(
        [
            FaultSpec(kind="poison_rows", target="tenant", tenant="A",
                      batch=2, poison="domain"),
            FaultSpec(kind="tenant_overload", target="tenant", tenant="C",
                      batch=shed_batch, rel="R", rows=4000),
        ]
    )
    mq.arm_faults(inj)

    from repro.stream import replication_width

    for i, b in enumerate(batches):
        if i == 4:
            # the forced-exhaustion kill: B alone loses a host it cannot
            # survive; everyone else never notices
            assert mq.fail_hosts("B", [0]) is None
            assert mq.status()["B"].state == FAILED
        if i == shed_batch:
            # cap capacity at 1.5x observed steady demand: normal load
            # fits, C's injected 4000-row burst does not
            mq.fair.capacity = 1.5 * sum(
                len(b[rel.name])
                * replication_width(mq.engine(nm).plan, rel.name)
                for nm in mq.serving()
                for rel in two_way().relations
            )
        reports = mq.ingest(b)
        if i == shed_batch:
            mq.fair.capacity = None
        if i == 2:
            assert reports["A"] is None  # poisoned batch never ingested
            assert mq.status()["A"].state == QUARANTINED
        if i >= 4:
            assert reports["B"] is None

    inj.assert_all_resolved()
    rep = inj.report()
    assert rep.contained == 2 and rep.unresolved == 0

    status = mq.status()
    assert status["A"].state == RUNNING  # reopened after backoff
    assert status["A"].reopens == 1
    assert status["B"].state == FAILED
    assert "RecoveryExhaustedError" in status["B"].last_error
    assert status["D"].state == RUNNING
    assert mq.serving() == ["A", "C", "D"]

    # the clean bystander is bit-identical to the single-tenant run and
    # never computed its own sketch pass
    d = mq.engine("D")
    assert (d.total_count, d.total_checksum) == (count, checksum)
    assert d.sketch_ingest_calls == 0
    for rs, rm in zip(solo.reports, d.reports):
        assert rs == rm

    # A matches solo exactly up to the poison batch, then resumes after
    # its quarantine window (missing exactly batches 2 and 3)
    a = mq.engine("A")
    assert [r.batch for r in a.reports] == [0, 1, 2, 3, 4, 5]
    for rs, rm in zip(solo.reports[:2], a.reports[:2]):
        assert rs == rm
    assert a.total_count < count

    # B matches solo exactly up to the kill boundary, then stopped
    bq = mq.engine("B")
    for rs, rm in zip(solo.reports[:4], bq.reports):
        assert (rs.total_count, rs.total_checksum) == (
            rm.total_count, rm.total_checksum,
        )
    assert len(bq.reports) == 4

    # C: the burst was shed off C alone; neighbors were never trimmed
    assert mq.fair.overload_shed["C"] > 0
    assert mq.fair.overload_shed["D"] == mq.fair.overload_shed["A"] == 0
    assert mq.engine("C").sketch_ingest_calls == 1  # the burst batch only

    # the shared sketch pass ran once per relation batch regardless of the
    # number of (eligible) absorbing tenants
    assert mq.shared_sketch_passes == 2 * N_BATCHES


def test_overload_sheds_only_the_offender():
    """A tenant-targeted overload burst under an aggregate cap is shed off
    the bursting tenant alone; neighbors stay bit-identical."""
    batches = _batches()
    solo = _solo_run(batches=batches)
    mq = MultiQueryEngine(
        [TenantSpec("hog", two_way(), _cfg()),
         TenantSpec("calm", two_way(), _cfg())]
    )
    inj = FaultInjector(
        [FaultSpec(kind="tenant_overload", target="tenant", tenant="hog",
                   batch=4, rel="R", rows=4000)]
    )
    mq.arm_faults(inj)
    from repro.stream import replication_width

    for i, b in enumerate(batches):
        if i == 4:
            # cap at 1.5x the observed steady demand: both tenants' normal
            # load fits, the injected 4000-row burst does not
            mq.fair.capacity = 1.5 * sum(
                len(b[rel.name])
                * replication_width(mq.engine(nm).plan, rel.name)
                for nm in mq.serving()
                for rel in two_way().relations
            )
        mq.ingest(b)
        if i == 4:
            mq.fair.capacity = None
    inj.assert_all_resolved()
    assert inj.report().contained == 1
    assert mq.fair.overload_shed["hog"] > 0
    assert mq.fair.overload_shed["calm"] == 0
    assert mq.fair.backpressure["hog"] == 1
    calm = mq.engine("calm")
    assert (calm.total_count, calm.total_checksum) == (
        solo.total_count, solo.total_checksum,
    )


# ---------------------------------------------------------- circuit breaker
def test_breaker_backoff_reopens_then_fails():
    """Repeated poison: exponential quarantine growth, bounded reopens,
    terminal FAILED — while the neighbor never misses a batch."""
    batches = _batches(12, seed=7)
    solo = _solo_run(batches=batches)
    mq = MultiQueryEngine(
        [TenantSpec("sick", two_way(), _cfg()),
         TenantSpec("ok", two_way(), _cfg())],
        TenancyPolicy(breaker_backoff=1, breaker_max_reopens=2),
    )
    # poison EVERY batch the sick tenant ever serves
    inj = FaultInjector(
        [FaultSpec(kind="poison_rows", target="tenant", tenant="sick",
                   batch=b, poison="nan") for b in range(12)]
    )
    mq.arm_faults(inj)
    states = []
    for b in batches:
        mq.ingest(b)
        states.append(mq.status()["sick"].state)
    # trip at 0 -> quarantined (backoff 1), reopen at 2 -> trip (backoff 2),
    # reopen at 5 -> trip: reopen budget (2) spent -> FAILED
    assert states[0] == QUARANTINED
    assert states[2] == QUARANTINED  # reopened and re-tripped same batch
    assert FAILED in states
    assert states[-1] == FAILED
    assert mq.status()["sick"].reopens == 2
    assert mq.engine("sick").total_count == 0  # nothing ever got in
    ok = mq.engine("ok")
    assert (ok.total_count, ok.total_checksum) == (
        solo.total_count, solo.total_checksum,
    )
    # poison specs for batches the victim never served are unresolved-free:
    # they simply never fired
    inj.assert_all_resolved()


def test_poison_rejected_before_any_state_mutation():
    """A poisoned batch must not touch the victim's state: totals, window
    and sketch all match the engine that never saw the batch."""
    batches = _batches()
    ref = StreamingJoinEngine(two_way(), _cfg())
    vic = StreamingJoinEngine(two_way(), _cfg())
    for i, b in enumerate(batches[:4]):
        ref.ingest(b)
        vic.ingest(b)
    bad = {"R": batches[4]["R"].astype(np.float64), "S": batches[4]["S"]}
    bad["R"][0, 0] = np.nan
    with pytest.raises(ValueError, match="poisoned batch"):
        vic.ingest(bad)
    assert (vic.total_count, vic.total_checksum) == (
        ref.total_count, ref.total_checksum,
    )
    ref.ingest(batches[5])
    vic.ingest(batches[5])  # engine still serves after the rejection
    assert (vic.total_count, vic.total_checksum) == (
        ref.total_count, ref.total_checksum,
    )


def test_poison_modes_all_rejected():
    eng = StreamingJoinEngine(two_way(), _cfg())
    good = _batches()[0]
    eng.ingest(good)
    n = eng.total_count
    cases = [
        {"R": good["R"], "S": good["S"][:, :1]},  # arity
        {"R": good["R"]},  # missing relation
        {"R": np.where(good["R"] == good["R"][0, 0], 2**40, good["R"]),
         "S": good["S"]},  # out of int32 routing domain
        {"R": good["R"].astype(object), "S": good["S"]},  # non-numeric
    ]
    for bad in cases:
        with pytest.raises(ValueError, match="poisoned batch"):
            eng.ingest(bad)
    assert eng.total_count == n


# ---------------------------------------------------- tenant-scoped recovery
def test_host_loss_repairs_one_tenant_only():
    """A survivable host kill in one tenant's domain: the victim recovers
    (possibly DEGRADED), the neighbor's fingerprints never move."""
    from repro.stream import RetentionPolicy

    batches = _batches()
    solo = _solo_run(batches=batches)
    rec_cfg = _cfg(
        retention=RetentionPolicy(window_batches=4),
        recovery=RecoveryPolicy(n_hosts=8),
    )
    mq = MultiQueryEngine(
        [TenantSpec("vic", two_way(), rec_cfg),
         TenantSpec("oth", two_way(), _cfg())]
    )
    for i, b in enumerate(batches):
        if i == 5:
            rep = mq.fail_hosts("vic", [2])
            assert rep is not None and rep.verified
            assert rep.tenant == "vic"
        mq.ingest(b)
    assert mq.status()["vic"].state in (RUNNING, DEGRADED)
    oth = mq.engine("oth")
    assert (oth.total_count, oth.total_checksum) == (
        solo.total_count, solo.total_checksum,
    )
    # the victim's window stays exact post-recovery
    vic = mq.engine("vic")
    w_count, w_checksum, _, _ = oracle_join(two_way(), vic.history_data())
    assert (vic.window_count, vic.window_checksum) == (w_count, w_checksum)


# ------------------------------------------------------------- checkpoints
def test_checkpoint_restore_bit_identical_for_all_tenants(tmp_path):
    """Kill -> restore mid-stream: every tenant (including a quarantined
    one) resumes bit-identically to the uninterrupted run."""
    batches = _batches()
    specs = [
        TenantSpec("t0", two_way(), _cfg(), weight=2.0),
        TenantSpec("t1", two_way(), _cfg()),
    ]
    pol = TenancyPolicy(breaker_backoff=2)

    def faults():
        return FaultInjector(
            [FaultSpec(kind="poison_rows", target="tenant", tenant="t1",
                       batch=3, poison="domain")]
        )

    full = MultiQueryEngine(specs, pol)
    full.arm_faults(faults())
    for b in batches:
        full.ingest(b)

    half = MultiQueryEngine(specs, pol)
    half.arm_faults(faults())
    for b in batches[:4]:
        half.ingest(b)
    half.save_checkpoint(str(tmp_path))
    del half

    resumed = MultiQueryEngine.restore(str(tmp_path), specs, pol)
    assert resumed.batches == 4
    assert resumed.status()["t1"].state == QUARANTINED
    for b in batches[4:]:
        resumed.ingest(b)

    for nm in ("t0", "t1"):
        a, b_ = full.engine(nm), resumed.engine(nm)
        assert (a.total_count, a.total_checksum) == (
            b_.total_count, b_.total_checksum,
        )
        assert [r.batch for r in a.reports] == [r.batch for r in b_.reports]
    sa, sb = full.status(), resumed.status()
    for nm in ("t0", "t1"):
        assert (sa[nm].state, sa[nm].failures, sa[nm].reopens) == (
            sb[nm].state, sb[nm].failures, sb[nm].reopens,
        )
    assert full.fair.overload_shed == resumed.fair.overload_shed


def test_checkpoint_rejects_tenant_set_mismatch(tmp_path):
    specs = [TenantSpec("a", two_way(), _cfg())]
    mq = MultiQueryEngine(specs)
    mq.ingest(_batches()[0])
    mq.save_checkpoint(str(tmp_path))
    other = [TenantSpec("zz", two_way(), _cfg())]
    with pytest.raises(ValueError, match="tenant"):
        MultiQueryEngine.restore(str(tmp_path), other)


# ----------------------------------------------------------------- validation
def test_tenant_spec_validation():
    with pytest.raises(ValueError, match="filename-safe"):
        TenantSpec("a/b", two_way(), _cfg())
    with pytest.raises(ValueError, match="reserved"):
        TenantSpec("__control__", two_way(), _cfg())
    with pytest.raises(ValueError, match="weight"):
        TenantSpec("a", two_way(), _cfg(), weight=0.0)
    with pytest.raises(ValueError, match="duplicate"):
        MultiQueryEngine(
            [TenantSpec("a", two_way(), _cfg()),
             TenantSpec("a", two_way(), _cfg())]
        )
    with pytest.raises(ValueError, match="breaker_backoff"):
        TenancyPolicy(breaker_backoff=0)
