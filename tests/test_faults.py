"""Fault-injection tests (DESIGN.md §8): every injected fault ends in a
retry-success or an explicit report — never a silent loss — and the join
fingerprint is fault-invariant wherever a result is produced at all.

Seams exercised (``repro.testing.faults``):
  * reduce shards under ``run_join_speculative`` — drop / duplicate /
    delay / preempt, per (shard, attempt), retried by the straggler runner;
  * sketch increments via ``FaultySketchTap`` — quality-only by contract:
    the engine's fingerprint must not move.
"""
import time

import numpy as np
import pytest

from repro.core import plan_shares_skew, two_way
from repro.data import paper_2way
from repro.mapreduce import oracle_join, run_join
from repro.mapreduce.executor import run_join_speculative
from repro.mapreduce.straggler import run_with_speculation
from repro.stream import RecoveryPolicy, StreamConfig, StreamingJoinEngine
from repro.testing import (
    FaultInjector,
    FaultSpec,
    FaultySketchTap,
    InjectedFault,
)

pytestmark = pytest.mark.faults


@pytest.fixture(scope="module")
def sharded_case():
    """A 2-way join with THREE pinned heavy hitters: the plan has >= 4
    residual joins, so the speculative executor genuinely runs >= 3 shards
    (a single-residual plan would make per-shard faults vacuous)."""
    rng = np.random.default_rng(0)
    n, domain = 3000, 2000
    heavy = np.concatenate([np.full(600, 5), np.full(500, 17), np.full(400, 42)])
    b_r = np.concatenate([heavy, rng.integers(0, domain, n - heavy.size)])
    r = np.stack([rng.integers(0, domain, n), b_r], 1).astype(np.int64)
    b_s = np.concatenate(
        [np.full(120, 5), np.full(100, 17), np.full(80, 42),
         rng.integers(0, domain, 300)]
    )
    s = np.stack([b_s, rng.integers(0, domain, 600)], 1).astype(np.int64)
    data = {"R": r, "S": s}
    plan = plan_shares_skew(two_way(), data, q=150)
    assert len(plan.residuals) >= 3, "fault targets must map to real shards"
    base = run_join(two_way(), data, plan, cap_factor=4.0)
    return data, plan, base


def _speculative(data, plan, injector, **kw):
    kw.setdefault("cap_factor", 4.0)
    kw.setdefault("n_shards", 3)
    return run_join_speculative(two_way(), data, plan, injector=injector, **kw)


# ------------------------------------------------------------ shard faults
def test_dropped_shard_is_retried(sharded_case):
    data, plan, base = sharded_case
    inj = FaultInjector([FaultSpec(kind="drop", shard_id=0, attempt=1)])
    res = _speculative(data, plan, inj)
    assert (res.count, res.checksum) == (base.count, base.checksum)
    assert res.comm_tuples == base.comm_tuples
    inj.assert_all_resolved()
    rep = inj.report()
    assert rep.injected >= 1 and rep.retried_ok >= 1 and rep.unresolved == 0


def test_preempted_shard_is_retried(sharded_case):
    """Preemption loses the computed result, not the input: the retry must
    reproduce it exactly (shards are deterministic pure functions)."""
    data, plan, base = sharded_case
    inj = FaultInjector([FaultSpec(kind="preempt", shard_id=1, attempt=1)])
    res = _speculative(data, plan, inj)
    assert (res.count, res.checksum) == (base.count, base.checksum)
    inj.assert_all_resolved()


def test_duplicate_shard_is_idempotent(sharded_case):
    """A raced duplicate submission must not double-count: the first result
    wins and counts/checksums are unchanged."""
    data, plan, base = sharded_case
    inj = FaultInjector([FaultSpec(kind="duplicate", shard_id=2)])
    res = _speculative(data, plan, inj)
    assert (res.count, res.checksum) == (base.count, base.checksum)
    assert res.comm_tuples == base.comm_tuples
    inj.assert_all_resolved()


def test_delayed_shard_still_exact(sharded_case):
    """A stalled attempt either finishes or is raced by a speculative
    backup; both orders end in the exact result."""
    data, plan, base = sharded_case
    inj = FaultInjector(
        [FaultSpec(kind="delay", shard_id=0, attempt=1, delay_s=0.4)]
    )
    res = _speculative(data, plan, inj, speculate_after=2.0)
    assert (res.count, res.checksum) == (base.count, base.checksum)
    inj.assert_all_resolved()


def test_every_fault_class_together(sharded_case):
    """All four fault classes in one run still converge to the exact
    result, with every event accounted for."""
    data, plan, base = sharded_case
    inj = FaultInjector(
        [
            FaultSpec(kind="drop", shard_id=0, attempt=1),
            FaultSpec(kind="preempt", shard_id=1, attempt=1),
            FaultSpec(kind="duplicate", shard_id=2),
            FaultSpec(kind="delay", shard_id=2, attempt=1, delay_s=0.2),
        ]
    )
    res = _speculative(data, plan, inj)
    assert (res.count, res.checksum) == (base.count, base.checksum)
    inj.assert_all_resolved()
    assert inj.report().unresolved == 0


def test_exhausted_attempts_reported_loudly(sharded_case):
    """A shard that fails every attempt must surface as an explicit error
    carrying the shard id — a partial join is never returned."""
    data, plan, _ = sharded_case
    inj = FaultInjector(
        [FaultSpec(kind="drop", shard_id=1, attempt=a) for a in (1, 2, 3)]
    )
    with pytest.raises(RuntimeError, match="shard 1"):
        _speculative(data, plan, inj, max_attempts=3)
    inj.assert_all_resolved()  # explicit report counts as resolved
    rep = inj.report()
    assert rep.reported >= 1 and rep.unresolved == 0


def test_straggler_runner_outcome_fields():
    """Unit-level: the runner retries failing attempts and marks terminal
    failures on the outcome instead of raising mid-run."""
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] == 1:
            raise InjectedFault("first attempt dies")
        return 42

    def doomed():
        raise InjectedFault("always dies")

    outcomes = run_with_speculation([flaky, doomed], max_attempts=2)
    assert outcomes[0].result == 42
    assert outcomes[0].attempts == 2 and outcomes[0].error is None
    assert outcomes[1].result is None
    assert outcomes[1].attempts == 2
    assert "always dies" in outcomes[1].error


def test_backup_latency_is_the_winning_attempts_own():
    """A zombie attempt fenced by the deadline must not pollute the
    winner's latency: ``elapsed_s`` is the winning attempt's own runtime,
    not the shard's first-submit age."""
    calls = []

    def hang_then_fast():
        first = len(calls) == 0
        calls.append(1)
        if first:
            time.sleep(1.0)  # zombie: sleeps past the deadline
            return "zombie"
        return "fresh"

    outcomes = run_with_speculation(
        [hang_then_fast],
        max_attempts=2,
        deadline_s=0.25,
        poll_interval_s=0.01,
        speculate_after=100.0,  # only the deadline re-issues here
    )
    o = outcomes[0]
    assert o.result == "fresh" and o.error is None
    assert o.attempts == 2
    # the retry returns in milliseconds; the shard has been pending ~0.3s.
    # First-submit-age timing would report >= 0.25 here.
    assert o.elapsed_s < 0.2


def test_terminal_error_race_one_outcome_per_shard():
    """A terminal error recorded while a speculative sibling is still in
    flight must not drop (or double) the shard's outcome: exactly one
    ``ShardOutcome`` per shard, carrying the error."""

    def doomed():
        time.sleep(0.2)  # slow enough that a backup overlaps
        raise InjectedFault("dies slowly")

    outcomes = run_with_speculation(
        [doomed, lambda: 1, lambda: 2],
        max_attempts=2,
        speculate_after=0.5,
        min_completed_before_speculation=2,
        poll_interval_s=0.01,
    )
    assert len(outcomes) == 3
    assert [o.shard_id for o in outcomes] == [0, 1, 2]
    o = outcomes[0]
    assert o.result is None and o.error is not None
    assert "dies slowly" in o.error
    assert o.attempts == 2
    assert outcomes[1].result == 1 and outcomes[2].result == 2


# --------------------------------------------------------- corrupt results
def test_corrupt_result_detected_and_retried(sharded_case):
    """A corrupted shard result fails CRC verification on receipt, counts
    as a failed attempt, and the retry reproduces the exact answer — a
    corrupt result is never returned."""
    data, plan, base = sharded_case
    inj = FaultInjector(
        [FaultSpec(kind="corrupt_result", shard_id=0, attempt=1)]
    )
    res = _speculative(data, plan, inj)
    assert (res.count, res.checksum) == (base.count, base.checksum)
    inj.assert_all_resolved()
    rep = inj.report()
    assert rep.injected == 1 and rep.retried_ok == 1 and rep.unresolved == 0


def test_corrupt_every_attempt_is_loud(sharded_case):
    """If every attempt's result is corrupted the shard fails explicitly
    with the checksum error — never a silently wrong join."""
    data, plan, _ = sharded_case
    inj = FaultInjector(
        [FaultSpec(kind="corrupt_result", shard_id=1, attempt=a)
         for a in (1, 2, 3)]
    )
    with pytest.raises(RuntimeError, match="ChecksumMismatch"):
        _speculative(data, plan, inj, max_attempts=3)
    inj.assert_all_resolved()


def test_corrupt_result_without_envelope_refused():
    """The corrupt seam requires the CRC envelope: faulting a run with
    ``checksum_results=False`` raises instead of silently corrupting."""
    inj = FaultInjector(
        [FaultSpec(kind="corrupt_result", shard_id=0, attempt=1)]
    )
    outcomes = run_with_speculation(
        [lambda: 7], injector=inj, checksum_results=False, max_attempts=2
    )
    # the refusal is an attempt failure -> the retry (unfaulted) succeeds
    assert outcomes[0].result == 7
    assert outcomes[0].attempts == 2


# ------------------------------------------------------------ sketch faults
def test_sketch_faults_are_quality_only():
    """Dropped/duplicated sketch increments may degrade planning but must
    not move the join fingerprint: correctness never depends on the
    sketch."""
    rng_a, rng_b = np.random.default_rng(11), np.random.default_rng(11)
    cfg = StreamConfig(q=60, decay=0.5, load_factor=2.0)
    clean = StreamingJoinEngine(two_way(), cfg)
    faulty = StreamingJoinEngine(two_way(), cfg)
    inj = FaultInjector(
        [
            FaultSpec(kind="drop", target="sketch", batch=1),
            FaultSpec(kind="duplicate", target="sketch", batch=2),
        ]
    )
    faulty.tracker = FaultySketchTap(faulty.tracker, inj)

    def batch(rng):
        data = paper_2way(rng, n_r=400, n_s=120, domain=500)
        return {"R": data["R"], "S": data["S"]}

    for _ in range(4):
        clean.ingest(batch(rng_a))
        faulty.ingest(batch(rng_b))
    assert (faulty.total_count, faulty.total_checksum) == (
        clean.total_count, clean.total_checksum,
    )
    count, checksum, _, _ = oracle_join(two_way(), faulty.history_data())
    assert (faulty.total_count, faulty.total_checksum) == (count, checksum)
    inj.resolve([])
    inj.assert_all_resolved()
    assert inj.report().sketch_tampered == 2


# ------------------------------------------- injector across restore (§8)
def test_fault_injector_active_across_restore_boundary(tmp_path):
    """Satellite: a ``FaultInjector`` stays armed across checkpoint/restore
    and already-fired faults do NOT re-fire.  Sketch faults are keyed by
    the tap's call counter (``first_call=len(reports)`` on the restored
    engine resumes it); host faults are keyed by absolute batch index and
    deduplicated by the injector's recorded events.  The restored run must
    converge to the same fingerprint as an uninterrupted reference."""
    specs = lambda: [
        FaultSpec(kind="drop", target="sketch", batch=1),  # pre-kill
        FaultSpec(kind="host_loss", target="host", host_id=2, batch=2),
        FaultSpec(kind="duplicate", target="sketch", batch=4),  # post-kill
        FaultSpec(kind="host_loss", target="host", host_id=5, batch=5),
    ]
    cfg = StreamConfig(
        q=60, decay=0.5, load_factor=2.0,
        recovery=RecoveryPolicy(n_hosts=8),
    )
    rng_ref = np.random.default_rng(21)
    batches = [
        paper_2way(rng_ref, n_r=300, n_s=100, domain=400) for _ in range(7)
    ]

    ref_inj = FaultInjector(specs())
    ref = StreamingJoinEngine(two_way(), cfg)
    ref.tracker = FaultySketchTap(ref.tracker, ref_inj)
    ref.arm_faults(ref_inj)
    for b in batches:
        ref.ingest(b)
    assert [r.batch for r in ref.recoveries] == [2, 5]

    inj = FaultInjector(specs())
    eng = StreamingJoinEngine(two_way(), cfg)
    eng.tracker = FaultySketchTap(eng.tracker, inj)
    eng.arm_faults(inj)
    for b in batches[:3]:  # batch-1 sketch fault and batch-2 loss fire
        eng.ingest(b)
    assert len(eng.recoveries) == 1
    eng.save_checkpoint(str(tmp_path))
    del eng  # killed

    resumed = StreamingJoinEngine.restore(str(tmp_path), two_way(), cfg)
    resumed.tracker = FaultySketchTap(
        resumed.tracker, inj, first_call=len(resumed.reports)
    )
    resumed.arm_faults(inj)  # SAME injector: its event log survives
    for b in batches[3:]:
        resumed.ingest(b)
    # pre-kill faults did not re-fire: one recovery each side of the kill
    assert [r.batch for r in resumed.recoveries] == [2, 5]
    assert inj.report().sketch_tampered == 2  # batch 1 once, batch 4 once
    inj.resolve([])
    inj.assert_all_resolved()
    assert (resumed.total_count, resumed.total_checksum) == (
        ref.total_count, ref.total_checksum,
    )
    count, checksum, _, _ = oracle_join(two_way(), resumed.history_data())
    assert (resumed.total_count, resumed.total_checksum) == (count, checksum)


# ----------------------------------------------- engine preempt-mid-stream
def test_engine_preempt_mid_batch_checkpoint_resume(tmp_path):
    """The engine-level preemption story: checkpoint, die between batches,
    restore, and converge to the same cumulative fingerprint as an
    uninterrupted run (the streaming analogue of a preempted shard)."""
    cfg = StreamConfig(q=60, decay=0.5, load_factor=2.0)
    rng_ref = np.random.default_rng(12)
    ref = StreamingJoinEngine(two_way(), cfg)
    batches = [
        paper_2way(rng_ref, n_r=300, n_s=100, domain=400) for _ in range(6)
    ]
    for b in batches:
        ref.ingest(b)

    eng = StreamingJoinEngine(two_way(), cfg)
    for b in batches[:3]:
        eng.ingest(b)
    eng.save_checkpoint(str(tmp_path))
    del eng  # preempted

    resumed = StreamingJoinEngine.restore(str(tmp_path), two_way(), cfg)
    for b in batches[3:]:
        resumed.ingest(b)
    assert resumed.reports == ref.reports
    assert (resumed.total_count, resumed.total_checksum) == (
        ref.total_count, ref.total_checksum,
    )
