"""Training substrate + serving engine tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model, make_batch
from repro.train import (
    AsyncCheckpointer,
    OptConfig,
    init_train_state,
    latest_step,
    load_checkpoint,
    make_train_step,
    plan_mesh_shape,
    restore_tree,
    save_checkpoint,
)
from repro.train.compression import compressed_psum
from repro.data.pipeline import TokenPipeline


OPT = OptConfig(lr=1e-3, warmup_steps=2, total_steps=20, grad_clip=1.0)


def _setup(name="olmo-1b", seed=0):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params, opt_state = init_train_state(model, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(model, OPT))
    rng = np.random.default_rng(seed)
    batch = make_batch(cfg, rng, batch=2, seq=32)
    return cfg, model, params, opt_state, step, batch


# ------------------------------------------------------------------ training
def test_loss_decreases_over_steps():
    cfg, model, params, opt_state, step, batch = _setup()
    losses = []
    for _ in range(8):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(losses))


def test_grad_clip_bounds_update():
    cfg, model, params, opt_state, step, batch = _setup()
    _, _, m = step(params, opt_state, batch)
    assert float(m["grad_norm"]) >= 0
    assert float(m["lr"]) <= OPT.lr


# --------------------------------------------------------------- checkpoints
def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg, model, params, opt_state, step, batch = _setup()
    for _ in range(3):
        params, opt_state, _ = step(params, opt_state, batch)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 3, {"params": params, "opt": opt_state})
    assert latest_step(d) == 3
    s, flat = load_checkpoint(d)
    restored = restore_tree({"params": params, "opt": opt_state}, flat)
    # identical continue: one more step from both must agree exactly
    p1, o1, m1 = step(params, opt_state, batch)
    p2, o2, m2 = step(restored["params"], restored["opt"], batch)
    assert float(m1["loss"]) == float(m2["loss"])


def test_checkpoint_keep_and_atomicity(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"a": jnp.arange(10.0)}
    for s in range(5):
        save_checkpoint(d, s, tree, keep=2)
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]
    assert latest_step(d) == 4


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ckpt")
    ck = AsyncCheckpointer(d, keep=2)
    ck.save(1, {"x": jnp.ones(4)})
    ck.save(2, {"x": jnp.ones(4) * 2})  # waits for save 1
    ck.wait()
    assert latest_step(d) == 2
    _, flat = load_checkpoint(d)
    np.testing.assert_array_equal(flat["x"], np.ones(4) * 2)


def test_resharding_restore_changes_sharding(tmp_path):
    # checkpoint saved "on one mesh" restores under any sharding spec
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    d = str(tmp_path / "ckpt")
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(d, 0, tree)
    _, flat = load_checkpoint(d)
    mesh = Mesh(np.array(jax.devices()).reshape(1), ("data",))
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    restored = restore_tree(tree, flat, shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding == shardings["w"]


# ------------------------------------------------------------------- elastic
def test_plan_mesh_shape_shrink():
    full = plan_mesh_shape(512, model_parallel=16, chips_per_pod=256)
    assert (full.pods, full.data, full.model) == (2, 16, 16)
    # lose one pod minus a few chips
    degraded = plan_mesh_shape(250, model_parallel=16, chips_per_pod=256)
    assert degraded.pods == 1 and degraded.model == 16
    assert degraded.chips_used == degraded.data * 16 <= 250
    with pytest.raises(ValueError):
        plan_mesh_shape(8, model_parallel=16)


# ----------------------------------------------------------------- pipeline
def test_pipeline_deterministic_and_resumable():
    p1 = TokenPipeline(vocab=100, batch=4, seq=8, seed=7)
    a = p1.next_batch()
    b = p1.next_batch()
    state = p1.state_dict()
    c = p1.next_batch()
    p2 = TokenPipeline(vocab=100, batch=4, seq=8, seed=7)
    p2.load_state_dict(state)
    np.testing.assert_array_equal(p2.next_batch(), c)
    # shards are disjoint streams
    s0 = TokenPipeline(vocab=100, batch=4, seq=8, seed=7, shard=0, num_shards=2)
    s1 = TokenPipeline(vocab=100, batch=4, seq=8, seed=7, shard=1, num_shards=2)
    assert not np.array_equal(s0.next_batch(), s1.next_batch())


def test_pipeline_prefetch():
    p = TokenPipeline(vocab=50, batch=2, seq=4, seed=1, prefetch=3)
    direct = [p.batch_at(i) for i in range(3)]
    p.start()
    got = [p.next_prefetched() for _ in range(3)]
    p.stop()
    for d, g in zip(direct, got):
        np.testing.assert_array_equal(d, g)


# ------------------------------------------------------------- compression
def test_compressed_psum_error_feedback():
    # single participant: compressed_psum must converge to the true sum via
    # error feedback (residual telescopes)
    import jax

    from repro.mapreduce.shuffle import shard_map

    def step(g, r):
        return shard_map(
            lambda gg, rr: compressed_psum(gg, rr, "x"),
            mesh=jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",)),
            in_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
            out_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
        )(g, r)

    g = jnp.asarray(np.random.default_rng(0).normal(size=64).astype(np.float32))
    r = jnp.zeros_like(g)
    acc_true = np.zeros(64, np.float64)
    acc_comp = np.zeros(64, np.float64)
    for _ in range(50):
        out, r = step(g, r)
        acc_true += np.asarray(g, np.float64)
        acc_comp += np.asarray(out, np.float64)
    # accumulated compressed sum tracks the true sum (error feedback works)
    rel = np.linalg.norm(acc_comp - acc_true) / np.linalg.norm(acc_true)
    assert rel < 0.01, rel


# -------------------------------------------------------------------- serve
@pytest.mark.parametrize("name", ["olmo-1b", "gemma3-4b", "rwkv6-3b", "zamba2-2.7b", "qwen2-moe-a2.7b"])
def test_decode_matches_forward_teacher_forcing(name):
    """Step-by-step decode must reproduce the parallel forward's logits —
    validates KV caches and recurrent states exactly."""
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    b, l = 2, 12
    tokens = rng.integers(0, cfg.vocab, size=(b, l)).astype(np.int32)

    # parallel forward hidden -> per-position logits
    kw = dict(dtype=jnp.float32, remat=False)
    if cfg.family == "moe":
        kw["capacity_factor"] = 8.0
    out = model.forward_hidden(params, {"tokens": jnp.asarray(tokens)}, **kw)
    h = out[0] if isinstance(out, tuple) else out
    if cfg.family == "ssm":  # rwkv: untied head
        table = params["lm_head"]["w"].T
    else:
        from repro.models.transformer import logits_table

        table = logits_table(cfg, params)
    ref_logits = np.asarray(h @ table.T.astype(h.dtype), np.float32)  # [B, L, V]

    # sequential decode over the same tokens
    cache = model.init_cache(b, 32, dtype=jnp.float32)
    got = []
    dkw = dict(dtype=jnp.float32)
    if cfg.family == "moe":
        dkw["capacity_factor"] = 8.0
    for t in range(l):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray(tokens[:, t : t + 1]), jnp.int32(t), **dkw
        )
        got.append(np.asarray(logits, np.float32))
    got = np.stack(got, axis=1)  # [B, L, V]
    np.testing.assert_allclose(got, ref_logits, rtol=2e-3, atol=2e-3)


def test_greedy_generate_and_bucket_server():
    from repro.serve import BucketServer, Request, greedy_generate

    cfg = get_config("olmo-1b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(5))
    rng = np.random.default_rng(5)
    prompts = rng.integers(0, cfg.vocab, size=(2, 8)).astype(np.int32)
    out = greedy_generate(model, params, prompts, max_new=5, dtype=jnp.float32)
    assert out.shape == (2, 5)
    # determinism
    out2 = greedy_generate(model, params, prompts, max_new=5, dtype=jnp.float32)
    np.testing.assert_array_equal(out, out2)

    server = BucketServer(model, params, max_batch=4, dtype=jnp.float32)
    for i in range(3):
        server.submit(Request(uid=i, prompt=prompts[i % 2], max_new=4))
    done = server.drain()
    assert sorted(c.uid for c in done) == [0, 1, 2]
    # batched result equals solo result for the same prompt
    solo = greedy_generate(model, params, prompts[:1], max_new=4, dtype=jnp.float32)
    batched = next(c for c in done if c.uid == 0)
    np.testing.assert_array_equal(batched.tokens, solo[0])


def test_fast_prefill_matches_scan_prefill():
    """transformer.prefill (parallel) must fill the KV cache identically to
    token-by-token scan_prefill — same logits now and one step later."""
    from repro.models.transformer import prefill
    from repro.serve import scan_prefill

    cfg = get_config("gemma3-4b").reduced()  # exercises local/global layers
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(11))
    rng = np.random.default_rng(11)
    b, l = 2, 10
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (b, l)), jnp.int32)

    cache_a = model.init_cache(b, 32, dtype=jnp.float32)
    logits_a, cache_a = prefill(cfg, params, prompts, cache_a, dtype=jnp.float32)
    cache_b = model.init_cache(b, 32, dtype=jnp.float32)
    logits_b, cache_b = scan_prefill(model, params, cache_b, prompts, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits_a), np.asarray(logits_b),
                               rtol=2e-4, atol=2e-4)
    # continue one decode step from both caches
    nxt = jnp.asarray(rng.integers(0, cfg.vocab, (b, 1)), jnp.int32)
    la, _ = model.decode_step(params, cache_a, nxt, jnp.int32(l), dtype=jnp.float32)
    lb, _ = model.decode_step(params, cache_b, nxt, jnp.int32(l), dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=2e-4, atol=2e-4)
