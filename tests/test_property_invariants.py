"""Hypothesis property tests for system invariants.

Invariants checked across randomized queries / data / skew:
  * executor output (count, checksum) == host oracle — no lost or duplicated
    join results, for any residual decomposition;
  * measured shuffle == the planner's cost model, exactly;
  * residual relevance masks partition every relation (each tuple belongs to
    exactly one type combination per attribute);
  * group_by_reducer never loses or duplicates tuples below capacity;
  * speculative shard execution returns every shard exactly once.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (
    make_query,
    plan_shares_skew,
    relevant_mask,
    three_way_paper,
    two_way,
)
from repro.core.residual import Combination, ORDINARY, enumerate_combinations
from repro.data import random_join_data
from repro.mapreduce import oracle_join, predicted_comm, run_join
from repro.mapreduce.local_join import group_by_reducer
from repro.mapreduce.straggler import run_with_speculation

QUERIES = {
    "two_way": two_way(),
    "three_way": three_way_paper(),
    "chain3": make_query({"R": ("A", "B"), "S": ("B", "C"), "T": ("C", "D")}),
}

SETTINGS = dict(
    deadline=None,
    max_examples=12,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


@st.composite
def join_case(draw):
    qname = draw(st.sampled_from(sorted(QUERIES)))
    query = QUERIES[qname]
    seed = draw(st.integers(0, 2**31 - 1))
    n = draw(st.integers(20, 300))
    domain = draw(st.integers(5, 200))
    skew = draw(st.booleans())
    rng = np.random.default_rng(seed)
    skew_attr = None
    hh_vals = None
    frac = 0.0
    if skew:
        skew_attr = draw(st.sampled_from(query.join_attributes))
        hh_vals = [int(v) for v in rng.integers(0, domain, size=draw(st.integers(1, 2)))]
        frac = draw(st.floats(0.1, 0.6))
    data = random_join_data(
        rng, query, n_per_relation=n, domain=domain,
        skew_attr=skew_attr, hh_values=hh_vals, hh_fraction=frac,
    )
    q_cap = draw(st.sampled_from([50, 120, 400]))
    return query, data, q_cap


@given(join_case())
@settings(**SETTINGS)
def test_executor_matches_oracle(case):
    query, data, q_cap = case
    plan = plan_shares_skew(query, data, q=q_cap)
    res = run_join(query, data, plan, cap_factor=6.0)
    count, checksum, _, _ = oracle_join(query, data)
    assert res.overflow == 0
    assert res.count == count
    assert res.checksum == checksum
    assert res.comm_tuples == predicted_comm(plan)


@given(join_case())
@settings(**SETTINGS)
def test_residuals_partition_relations(case):
    query, data, q_cap = case
    plan = plan_shares_skew(query, data, q=q_cap)
    hh = plan.hh_values
    if not hh:
        return
    combos = enumerate_combinations(hh)
    for rel in query.relations:
        arr = np.asarray(data[rel.name])
        # restrict combos to the types of attributes THIS relation contains:
        # each tuple must match exactly one such restricted combination
        own = [a for a in sorted(hh) if a in rel.attrs]
        seen = set()
        total = np.zeros(arr.shape[0], dtype=int)
        for combo in combos:
            cd = combo.as_dict()
            key = tuple((a, cd[a]) for a in own)
            if key in seen:
                continue
            seen.add(key)
            restricted = Combination.of(dict(key) | {a: ORDINARY for a in sorted(hh) if a not in rel.attrs})
            # relevant_mask only constrains attrs present in the relation
            total += relevant_mask(arr, rel.attrs, restricted, hh).astype(int)
        assert (total == 1).all()


@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 64),
    st.integers(1, 16),
    st.integers(8, 128),
)
@settings(**SETTINGS)
def test_group_by_reducer_conserves_tuples(seed, k, arity, m):
    rng = np.random.default_rng(seed)
    dests = rng.integers(-1, k, size=m).astype(np.int32)
    rows = rng.integers(0, 1000, size=(m, arity)).astype(np.int32)
    cap = int(m)  # cap >= any possible load -> zero overflow
    import jax.numpy as jnp

    bins, valid, loads, overflow = group_by_reducer(
        jnp.asarray(dests), jnp.asarray(rows), k, cap
    )
    assert int(overflow) == 0
    # loads count arrivals per reducer
    expect_loads = np.bincount(dests[dests >= 0], minlength=k)
    np.testing.assert_array_equal(np.asarray(loads), expect_loads)
    # multiset of (dest, row) preserved
    got = []
    b, v = np.asarray(bins), np.asarray(valid)
    for kk in range(k):
        for c in range(cap):
            if v[kk, c]:
                got.append((kk, tuple(b[kk, c])))
    want = [
        (int(d), tuple(rows[i])) for i, d in enumerate(dests) if d >= 0
    ]
    assert sorted(got) == sorted(want)


def test_speculation_covers_all_shards():
    import time

    def make(i):
        def fn():
            time.sleep(0.25 if i == 3 else 0.01)  # shard 3 straggles
            return i * i
        return fn

    outcomes = run_with_speculation(
        [make(i) for i in range(8)], max_workers=4, speculate_after=3.0
    )
    assert [o.shard_id for o in outcomes] == list(range(8))
    assert [o.result for o in outcomes] == [i * i for i in range(8)]
    assert any(o.speculated for o in outcomes)  # the straggler got a backup
