"""Residual-join enumeration, subsumption, HH detection, planner (§4-§6)."""
import numpy as np
import pytest

from repro.core import (
    Combination,
    ORDINARY,
    detect_heavy_hitters,
    enumerate_combinations,
    plan_plain_shares,
    plan_shares_skew,
    relevant_sizes,
    three_way_paper,
    two_way,
)
from repro.core.heavy_hitters import CountMinSketch, exact_heavy_hitters
from repro.data import paper_2way, paper_3way


RNG = np.random.default_rng(0)


# ------------------------------------------------------------ heavy hitters
def test_exact_heavy_hitters():
    col = np.array([1, 1, 1, 2, 2, 3, 9, 9, 9, 9])
    vals, counts = exact_heavy_hitters(col, 3)
    assert vals.tolist() == [9, 1]
    assert counts.tolist() == [4, 3]


def test_count_min_sketch_upper_bound_and_merge():
    rng = np.random.default_rng(1)
    keys_a = rng.integers(0, 1000, 5000)
    keys_b = np.concatenate([rng.integers(0, 1000, 3000), np.full(2000, 42)])
    s1 = CountMinSketch(width=2048, depth=5, seed=0)
    s2 = CountMinSketch(width=2048, depth=5, seed=0)
    s1.update(keys_a)
    s2.update(keys_b)
    merged = s1.merge(s2)
    true_count = int((keys_a == 42).sum() + (keys_b == 42).sum())
    est = int(merged.estimate(np.array([42]))[0])
    assert est >= true_count  # CMS never underestimates
    assert est <= true_count + 0.02 * merged.total  # and is reasonably tight
    vals, _ = merged.heavy_hitters(np.concatenate([keys_a, keys_b]), 1500)
    assert 42 in vals.tolist()


def test_detect_heavy_hitters_paper_3way():
    data = paper_3way(np.random.default_rng(2))
    q = three_way_paper()
    hh = detect_heavy_hitters(q, data, threshold=100, candidate_attrs=("B", "C"))
    assert set(hh["B"].tolist()) == {11, 13}
    assert set(hh["C"].tolist()) == {17}


# ------------------------------------------------------------- combinations
def test_enumerate_combinations_count():
    # paper §4.1: B with 2 HHs, C with 3 HHs -> 3 * 4 = 12 combinations
    hh = {"B": np.array([1, 2]), "C": np.array([10, 20, 30])}
    combos = enumerate_combinations(hh)
    assert len(combos) == 12
    # exactly one all-ordinary
    assert sum(1 for c in combos if not c.pinned) == 1


def test_enumerate_combinations_example5():
    # Ex. 5: B has b1,b2; C has c1 -> 6 residual joins
    hh = {"B": np.array([11, 13]), "C": np.array([17])}
    assert len(enumerate_combinations(hh)) == 6


def test_relevant_sizes_partition():
    # §4.1: S(B,E,C) with B: 2 HH and C: 1 HH partitions into 3*2=6 disjoint
    # pieces; all combos' S-sizes must sum to |S|.
    data = paper_3way(np.random.default_rng(3))
    q = three_way_paper()
    hh = {"B": np.array([11, 13]), "C": np.array([17])}
    combos = enumerate_combinations(hh)
    s_total = sum(relevant_sizes(q, data, c, hh)["S"] for c in combos)
    assert s_total == data["S"].shape[0]
    # R(A,B) has only B -> its 3 pieces each counted once per C-type (2x)
    r_total = sum(relevant_sizes(q, data, c, hh)["R"] for c in combos)
    assert r_total == 2 * data["R"].shape[0]


# ------------------------------------------------------------------ planner
def test_plan_2way_has_two_residuals():
    # §5.3: one residual without HH, one with the single HH
    data = paper_2way(np.random.default_rng(4))
    plan = plan_shares_skew(two_way(), data, q=500)
    assert len(plan.residuals) == 2
    pins = sorted(str(r.combo) for r in plan.residuals)
    assert any("B=_" in p for p in pins)
    assert any("B=7" in p for p in pins)
    # HH residual: B pinned -> grid over A and C (Example 2's x*y rectangle)
    hh_res = next(r for r in plan.residuals if r.combo.pinned)
    assert set(hh_res.grid_attrs) <= {"A", "C"}
    # capacity respected in expectation
    for r in plan.residuals:
        assert r.solution.cost / r.k_budget <= plan.q * 1.001


def test_plan_3way_residual_count():
    data = paper_3way(np.random.default_rng(5))
    # q=100: B's HHs (~200 tuples each) and C's HH (~400) all exceed both the
    # detection threshold and the subsumption bar -> Ex. 5/6's 3*2=6 residuals
    plan = plan_shares_skew(three_way_paper(), data, q=100)
    assert len(plan.residuals) == 6
    assert set(plan.hh_values) == {"B", "C"}
    # reducer id blocks must not overlap
    spans = sorted((r.reducer_offset, r.reducer_offset + r.num_reducers) for r in plan.residuals)
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 <= b0
    assert plan.total_reducers == spans[-1][1]


def test_subsumption_demotes_non_skewed_values():
    # A "heavy hitter" that is barely above uniform should be demoted when
    # the ordinary shares already spread it (paper §5.1 subsumption).
    rng = np.random.default_rng(6)
    n, domain = 5000, 50
    data = {
        "R": rng.integers(0, domain, size=(n, 2)).astype(np.int64),
        "S": rng.integers(0, domain, size=(n, 2)).astype(np.int64),
    }
    # threshold low enough that common values qualify as "HH" spuriously
    plan = plan_shares_skew(two_way(), data, q=2 * n, hh_threshold=n / domain * 1.2)
    # with q = 2n the whole join fits one reducer: x_B = 1 -> every HH is
    # harmless -> all demoted, single residual
    assert len(plan.residuals) == 1
    assert not plan.residuals[0].combo.pinned


def test_plain_shares_baseline():
    data = paper_2way(np.random.default_rng(7))
    plan = plan_plain_shares(two_way(), data, k=32)
    assert len(plan.residuals) == 1
    r = plan.residuals[0]
    # 2-way: B gets the whole share budget
    assert r.solution.int_shares["B"] >= 1
    assert r.num_reducers <= 32


def test_plan_predicted_cost_close_to_theory():
    # §9.1 theory: HH residual cost ~= 2 sqrt(k r s) over HH tuples
    from repro.core import two_way_skew_cost

    rng = np.random.default_rng(8)
    data = paper_2way(rng, n_r=20000, n_s=2000)
    plan = plan_shares_skew(two_way(), data, q=500)
    hh_res = next(r for r in plan.residuals if r.combo.pinned)
    r_hh, s_hh = hh_res.sizes["R"], hh_res.sizes["S"]
    theory = two_way_skew_cost(r_hh, s_hh, hh_res.num_reducers)
    assert hh_res.solution.int_cost == pytest.approx(theory, rel=0.35)
