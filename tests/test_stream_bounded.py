"""Bounded-state streaming tests (DESIGN.md §8): windowed retention,
admission control, checkpoint/restore, and replan-thrash hysteresis.

The load-bearing invariants:
  * with retention, the engine's (window_count, window_checksum) equals the
    batch oracle on the retained suffix after ANY prefix of batches —
    retraction is exact, not approximate;
  * peak carried state is flat under retention where the unbounded engine
    grows monotonically (the soak);
  * admission accounting is exact: offered == ingested + backlog + shed;
  * checkpoint -> kill -> restore -> continue produces bit-identical
    reports and fingerprints to an uninterrupted run.
"""
import numpy as np
import pytest

from repro.core import two_way
from repro.mapreduce import oracle_join
from repro.stream import (
    AdmissionPolicy,
    RetentionPolicy,
    StreamConfig,
    StreamingJoinEngine,
)


def _zipf_batch(rng, shift, n_r=240, n_s=80, domain=600, a=1.6):
    """Small 2-way batch; Zipf-heavy B values sit at ``shift`` (mod domain)."""
    b_r = ((rng.zipf(a, n_r) - 1) + shift) % domain
    b_s = ((rng.zipf(a, n_s) - 1) + shift) % domain
    r = np.stack([rng.integers(0, domain, n_r), b_r], 1).astype(np.int64)
    s = np.stack([b_s, rng.integers(0, domain, n_s)], 1).astype(np.int64)
    return {"R": r, "S": s}


class FakeClock:
    """Deterministic injectable clock for TTL retention."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


# ------------------------------------------------------- windowed retention
def test_window_fingerprint_matches_oracle_every_batch():
    """After every batch, (window_count, window_checksum) == the batch
    oracle on the retained suffix — retraction telescopes exactly."""
    rng = np.random.default_rng(0)
    cfg = StreamConfig(
        q=60, decay=0.5, load_factor=2.0,
        retention=RetentionPolicy(window_batches=3),
    )
    eng = StreamingJoinEngine(two_way(), cfg)
    for i in range(10):
        shift = 0 if i < 5 else 300
        report = eng.ingest(_zipf_batch(rng, shift))
        count, checksum, _, _ = oracle_join(two_way(), eng.history_data())
        assert (eng.window_count, eng.window_checksum) == (count, checksum)
        assert len(eng._retained_ids) <= 3
        assert report.window_count == eng.window_count
    assert eng.expired_batches == 7
    assert eng.total_retracted > 0
    # cumulative fingerprint only ever grows (expiry never un-emits)
    totals = [r.total_count for r in eng.reports]
    assert totals == sorted(totals)


def test_window_fingerprint_matches_oracle_fused():
    """Same invariant on the fused sorted-merge path (batch-id expiry in
    the SortedDeltaIndex), including across a drift replan."""
    rng = np.random.default_rng(1)
    cfg = StreamConfig(
        q=60, decay=0.5, load_factor=2.0, fused_ingest=True,
        retention=RetentionPolicy(window_batches=4),
    )
    eng = StreamingJoinEngine(two_way(), cfg)
    for i in range(12):
        shift = 0 if i < 6 else 300
        eng.ingest(_zipf_batch(rng, shift))
    assert eng.fused_batches == 12
    assert eng.expired_batches == 8
    count, checksum, _, _ = oracle_join(two_way(), eng.history_data())
    assert (eng.window_count, eng.window_checksum) == (count, checksum)
    assert eng.replan_count >= 1  # drift fired while the window slid


def test_ttl_retention_with_injectable_clock():
    clock = FakeClock()
    cfg = StreamConfig(
        q=60, decay=0.5, load_factor=2.0,
        retention=RetentionPolicy(ttl_seconds=10.0),
    )
    eng = StreamingJoinEngine(two_way(), cfg, clock=clock)
    rng = np.random.default_rng(2)
    for _ in range(8):
        clock.t += 4.0  # each batch ages the window by 4s -> keep last ~3
        eng.ingest(_zipf_batch(rng, 0))
    assert eng.expired_batches > 0
    assert len(eng._retained_ids) <= 3
    count, checksum, _, _ = oracle_join(two_way(), eng.history_data())
    assert (eng.window_count, eng.window_checksum) == (count, checksum)


def test_recompute_refuses_after_expiry():
    """The distributed cross-check must not silently compare a truncated
    replay against the full-stream fingerprint."""
    rng = np.random.default_rng(3)
    cfg = StreamConfig(
        q=60, decay=0.5, load_factor=2.0,
        retention=RetentionPolicy(window_batches=2),
    )
    eng = StreamingJoinEngine(two_way(), cfg)
    for _ in range(5):
        eng.ingest(_zipf_batch(rng, 0))
    with pytest.raises(RuntimeError, match="window=True"):
        eng.recompute_distributed()
    res = eng.recompute_distributed(window=True, cap_factor=8.0,
                                    route_cap_factor=8.0)
    assert (res.count, res.checksum) == (eng.window_count, eng.window_checksum)


@pytest.mark.soak
def test_soak_carried_state_flat_under_retention():
    """>= 200 drifting-Zipf batches: peak per-reducer carried state stays
    flat with retention where the unbounded engine grows monotonically."""
    n_batches = 200
    base_kw = dict(q=60, decay=0.5, load_factor=2.0, fused_ingest=True)
    bounded = StreamingJoinEngine(
        two_way(),
        StreamConfig(retention=RetentionPolicy(window_batches=5), **base_kw),
    )
    unbounded = StreamingJoinEngine(two_way(), StreamConfig(**base_kw))
    rng_b, rng_u = np.random.default_rng(4), np.random.default_rng(4)
    carried_b, carried_u = [], []
    for i in range(n_batches):
        shift = (i // 50) * 150  # drift every 50 batches
        rb = bounded.ingest(_zipf_batch(rng_b, shift, n_r=120, n_s=40))
        ru = unbounded.ingest(_zipf_batch(rng_u, shift, n_r=120, n_s=40))
        carried_b.append(rb.carried_tuples)
        carried_u.append(ru.carried_tuples)
    # unbounded: monotonic growth, ends at the whole stream's emissions
    assert carried_u[-1] == max(carried_u)
    assert carried_u[-1] > 10 * max(carried_b)
    # bounded: flat — the second-half peak stays within 1.5x the peak seen
    # once the window first filled (replans may widen per-tuple replication,
    # but there is no growth with stream length)
    assert max(carried_b[n_batches // 2 :]) <= 1.5 * max(carried_b[5:50])
    assert bounded.expired_batches == n_batches - 5
    # exactness survived 200 retractions + replans: window == oracle
    count, checksum, _, _ = oracle_join(two_way(), bounded.history_data())
    assert (bounded.window_count, bounded.window_checksum) == (count, checksum)


# ----------------------------------------------------------- admission
def test_admission_exact_accounting_and_drain():
    """offered == ingested + backlog + shed, exactly; after the inflow
    stops, the backlog drains and the fingerprint equals the oracle on
    everything admitted."""
    cfg = StreamConfig(
        q=60, decay=0.5, load_factor=2.0,
        admission=AdmissionPolicy(headroom=1.0, max_backlog_rows=400),
    )
    eng = StreamingJoinEngine(two_way(), cfg)
    rng = np.random.default_rng(5)
    offered = {"R": 0, "S": 0}
    for _ in range(4):  # oversized batches: force deferral (and shedding)
        batch = _zipf_batch(rng, 0, n_r=2000, n_s=700)
        offered["R"] += len(batch["R"])
        offered["S"] += len(batch["S"])
        report = eng.ingest(batch)
    assert report.deferred["R"] > 0  # backlog is non-empty mid-stream
    assert eng.total_shed > 0  # overflow was shed, explicitly
    empty = {"R": np.zeros((0, 2), np.int64), "S": np.zeros((0, 2), np.int64)}
    for _ in range(40):  # drain
        report = eng.ingest(empty)
        if report.total_count and not any(report.deferred.values()):
            break
    assert not any(report.deferred.values()), "backlog failed to drain"
    for nm in ("R", "S"):
        ingested = sum(len(b) for b in eng._history[nm])
        backlog = len(eng._controller.backlog[nm])
        shed = sum(r.shed[nm] for r in eng.reports)
        assert ingested + backlog + shed == offered[nm]
        assert backlog == 0
    count, checksum, _, _ = oracle_join(two_way(), eng.history_data())
    assert (eng.total_count, eng.total_checksum) == (count, checksum)


def test_admission_off_admits_everything():
    rng = np.random.default_rng(6)
    eng = StreamingJoinEngine(
        two_way(), StreamConfig(q=60, decay=0.5, load_factor=2.0)
    )
    batch = _zipf_batch(rng, 0, n_r=5000, n_s=1500)
    report = eng.ingest(batch)
    assert not any(report.deferred.values())
    assert not any(report.shed.values())
    assert len(eng._history["R"][0]) == 5000


# ---------------------------------------------------- checkpoint / restore
def _ingest_n(eng, rng, n, start=0):
    reports = []
    for i in range(start, start + n):
        shift = 0 if i < 4 else 300  # drift lands after the checkpoint
        reports.append(eng.ingest(_zipf_batch(rng, shift)))
    return reports


@pytest.mark.faults
@pytest.mark.parametrize("fused", [False, True])
def test_checkpoint_restore_bit_identical(tmp_path, fused):
    """save -> kill -> restore -> continue reproduces the uninterrupted
    run's reports and fingerprints bit-for-bit, including the post-restore
    drift replan decision."""
    cfg = StreamConfig(
        q=60, decay=0.5, load_factor=2.0, fused_ingest=fused,
        retention=RetentionPolicy(window_batches=4),
        admission=AdmissionPolicy(headroom=4.0),
    )
    # uninterrupted reference
    ref = StreamingJoinEngine(two_way(), cfg)
    _ingest_n(ref, np.random.default_rng(7), 8)

    # interrupted twin: same batches, killed after batch 3
    eng = StreamingJoinEngine(two_way(), cfg)
    rng = np.random.default_rng(7)
    _ingest_n(eng, rng, 3)
    eng.save_checkpoint(str(tmp_path))
    del eng  # the "kill"

    resumed = StreamingJoinEngine.restore(str(tmp_path), two_way(), cfg)
    assert len(resumed.reports) == 3
    _ingest_n(resumed, rng, 5, start=3)

    assert resumed.reports == ref.reports  # bit-identical telemetry
    assert (resumed.total_count, resumed.total_checksum) == (
        ref.total_count, ref.total_checksum,
    )
    assert (resumed.window_count, resumed.window_checksum) == (
        ref.window_count, ref.window_checksum,
    )
    assert resumed.replan_count == ref.replan_count
    np.testing.assert_array_equal(resumed._loads, ref._loads)


@pytest.mark.faults
def test_restore_rejects_wrong_kind(tmp_path):
    from repro.train.checkpoint import save_checkpoint

    save_checkpoint(str(tmp_path), step=0, tree={"x": np.zeros(3)})
    with pytest.raises(ValueError, match="not a stream engine"):
        StreamingJoinEngine.restore(
            str(tmp_path), two_way(),
            StreamConfig(q=60, decay=0.5, load_factor=2.0),
        )


# ------------------------------------------------------------- hysteresis
def test_drift_hysteresis_no_replan_thrash():
    """A heavy value whose rate oscillates inside the (fade_factor*q, pin)
    hysteresis gap must not replan every batch: once pinned it stays
    pinned (load is spread), and its rate never sinks below the fade
    threshold, so the replan count stays bounded."""
    q = 60.0
    cfg = StreamConfig(q=q, decay=0.5, load_factor=2.0, fade_factor=0.25)
    eng = StreamingJoinEngine(two_way(), cfg)
    rng = np.random.default_rng(8)
    hot = 7
    for i in range(16):
        # oscillate the hot value's per-batch rate between ~0.6q and ~1.5q:
        # above fade_factor*q always, crossing the pin threshold (~q) often
        n_hot = int(1.5 * q) if i % 2 == 0 else int(0.6 * q)
        b_r = np.full(n_hot, hot)
        r = np.stack([rng.integers(0, 600, n_hot), b_r], 1).astype(np.int64)
        s_vals = np.concatenate([[hot] * 5, rng.integers(0, 600, 75)])
        s = np.stack([s_vals, rng.integers(0, 600, 80)], 1).astype(np.int64)
        eng.ingest({"R": r, "S": s})
    assert eng.replan_count <= 2, (
        f"replan thrash: {eng.replan_count} replans in 16 batches; "
        f"reasons={[r.drift_reason for r in eng.reports if r.replanned]}"
    )
    count, checksum, _, _ = oracle_join(two_way(), eng.history_data())
    assert (eng.total_count, eng.total_checksum) == (count, checksum)


# ------------------------------------------------- retention edge cases
def test_window_of_one_batch():
    """window_batches=1: only the current batch is retained; after every
    ingest the window fingerprint equals the oracle on that batch alone."""
    rng = np.random.default_rng(11)
    eng = StreamingJoinEngine(
        two_way(),
        StreamConfig(
            q=60, decay=0.5, load_factor=2.0,
            retention=RetentionPolicy(window_batches=1),
        ),
    )
    for i in range(6):
        batch = _zipf_batch(rng, 0 if i < 3 else 300)
        eng.ingest(batch)
        count, checksum, _, _ = oracle_join(two_way(), batch)
        assert (eng.window_count, eng.window_checksum) == (count, checksum)
        assert sum(len(b) for b in eng._history["R"]) == len(batch["R"])
    assert eng.expired_batches == 5


def test_all_rows_expired_window_then_recovers():
    """When every retained batch expires (only zero-row batches remain in
    the window), the fingerprint collapses to (0, 0) and the engine keeps
    serving: the next real batch rebuilds an exact window."""
    rng = np.random.default_rng(12)
    eng = StreamingJoinEngine(
        two_way(),
        StreamConfig(
            q=60, decay=0.5, load_factor=2.0,
            retention=RetentionPolicy(window_batches=2),
        ),
    )
    eng.ingest(_zipf_batch(rng, 0))
    eng.ingest(_zipf_batch(rng, 0))
    assert eng.window_count > 0
    empty = {"R": np.zeros((0, 2), np.int64), "S": np.zeros((0, 2), np.int64)}
    eng.ingest(empty)
    eng.ingest(empty)  # both real batches have now expired
    assert (eng.window_count, eng.window_checksum) == (0, 0)
    assert all(len(b) == 0 for b in eng._history["R"])
    fresh = _zipf_batch(rng, 300)
    eng.ingest(fresh)
    count, checksum, _, _ = oracle_join(two_way(), eng.history_data())
    assert count > 0
    assert (eng.window_count, eng.window_checksum) == (count, checksum)


def test_zero_row_batch_mid_window_is_a_noop():
    """A zero-row batch inside the window must not move the fingerprint,
    expire anything early, or perturb the carried state."""
    rng = np.random.default_rng(13)
    eng = StreamingJoinEngine(
        two_way(),
        StreamConfig(
            q=60, decay=0.5, load_factor=2.0,
            retention=RetentionPolicy(window_batches=8),
        ),
    )
    for _ in range(3):
        eng.ingest(_zipf_batch(rng, 0))
    before = (
        eng.window_count, eng.window_checksum,
        eng.total_count, eng.total_checksum, eng.expired_batches,
    )
    carried_before = sum(
        int(occ.sum()) for _, _, occ in eng._state.values()
    )
    empty = {"R": np.zeros((0, 2), np.int64), "S": np.zeros((0, 2), np.int64)}
    report = eng.ingest(empty)
    assert report.delta_count == 0
    assert report.retracted_count == 0
    assert (
        eng.window_count, eng.window_checksum,
        eng.total_count, eng.total_checksum, eng.expired_batches,
    ) == before
    assert sum(int(occ.sum()) for _, _, occ in eng._state.values()) == (
        carried_before
    )
    # the stream continues exactly from where it was
    eng.ingest(_zipf_batch(rng, 0))
    count, checksum, _, _ = oracle_join(two_way(), eng.history_data())
    assert (eng.window_count, eng.window_checksum) == (count, checksum)


# ------------------------------------------------- admission validation
def test_admission_policy_rejects_degenerate_knobs():
    for bad in (0.0, -1.0, float("nan"), float("inf")):
        with pytest.raises(ValueError, match="headroom"):
            AdmissionPolicy(headroom=bad)
    with pytest.raises(ValueError, match="max_backlog_rows"):
        AdmissionPolicy(headroom=1.0, max_backlog_rows=-1)
    with pytest.raises(ValueError, match="min_admit"):
        AdmissionPolicy(headroom=1.0, min_admit=0)


def test_admission_controller_rejects_degenerate_capacity():
    from repro.stream import AdmissionController

    pol = AdmissionPolicy(headroom=1.0)
    for bad_q in (0.0, -5.0, float("nan"), float("inf")):
        with pytest.raises(ValueError, match="capacity"):
            AdmissionController(pol, two_way(), bad_q)
    ctl = AdmissionController(pol, two_way(), 60.0)
    for bad in (0.0, -0.5, 1.5, float("nan"), float("inf")):
        with pytest.raises(ValueError, match="factor"):
            ctl.set_capacity(bad)
    ctl.set_capacity(0.5)  # a legal degrade still works
    assert ctl.capacity_factor == 0.5


def test_weighted_fair_allocation_validation_and_invariants():
    from repro.stream import weighted_fair_allocation

    with pytest.raises(ValueError, match="capacity"):
        weighted_fair_allocation({"a": 1.0}, {"a": 1.0}, float("nan"))
    with pytest.raises(ValueError, match="weight"):
        weighted_fair_allocation({"a": 1.0}, {"a": 0.0}, 10.0)
    with pytest.raises(ValueError, match="demand"):
        weighted_fair_allocation({"a": -1.0}, {"a": 1.0}, 10.0)
    # work-conserving, demand-capped, under-share tenants untouched
    alloc = weighted_fair_allocation(
        {"a": 10.0, "b": 100.0}, {"a": 1.0, "b": 1.0}, 60.0
    )
    assert alloc["a"] == 10.0  # under fair share: never trimmed
    assert alloc["b"] == 50.0  # soaks up the surplus
    assert sum(alloc.values()) == 60.0
