"""Roofline reader sanity: table builds from dry-run artifacts when present."""
import os

import pytest

from benchmarks.roofline import ART_DIR, build_table, model_flops, render_markdown


def test_model_flops_formulas():
    # train: 6*N*D; decode: 2*N*batch — spot checks
    mf = model_flops("olmo-1b", "train_4k")
    assert 6.5e15 < mf < 9e15  # 6 * ~1.2B * 1.05M tokens
    md = model_flops("olmo-1b", "decode_32k")
    assert 2.5e11 < md < 4e11  # 2 * ~1.2B * 128


@pytest.mark.skipif(
    not os.path.isdir(ART_DIR) or not os.listdir(ART_DIR),
    reason="dry-run artifacts not generated",
)
def test_build_table_from_artifacts():
    rows = build_table("pod16x16")
    assert len(rows) == 40  # 10 archs x 4 shapes (ok + skipped)
    ok = [r for r in rows if "skipped" not in r]
    assert len(ok) == 32
    for r in ok:
        assert r["compute_s"] > 0
        assert r["memory_s"] > 0
        assert r["dominant"] in ("compute", "memory", "collective")
        assert 0 < r["useful_ratio"] < 10
    md = render_markdown(rows)
    assert md.count("\n") == 41  # header + separator + 40 rows
