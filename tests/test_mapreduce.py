"""Integration tests: the JAX MapReduce join engine vs the host oracle."""
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (
    make_query,
    plan_plain_shares,
    plan_shares_skew,
    three_way_paper,
    triangle,
    two_way,
)
from repro.data import paper_2way, paper_3way, random_join_data
from repro.mapreduce import (
    naive_two_way,
    oracle_join,
    predicted_comm,
    run_join,
)


def _check(query, data, plan, cap_factor=4.0):
    res = run_join(query, data, plan, cap_factor=cap_factor)
    count, checksum, _, _ = oracle_join(query, data)
    assert res.overflow == 0, f"capacity overflow: {res.overflow}"
    assert res.count == count
    assert res.checksum == checksum
    return res


# ------------------------------------------------------------- correctness
def test_2way_skewed_matches_oracle():
    data = paper_2way(np.random.default_rng(0), n_r=3000, n_s=600, domain=2000)
    plan = plan_shares_skew(two_way(), data, q=200)
    assert len(plan.residuals) == 2
    res = _check(two_way(), data, plan)
    assert res.count > 0


def test_2way_comm_matches_prediction():
    data = paper_2way(np.random.default_rng(1), n_r=3000, n_s=600, domain=2000)
    plan = plan_shares_skew(two_way(), data, q=200)
    res = run_join(two_way(), data, plan, cap_factor=4.0)
    # measured shuffle == the cost model, exactly (deterministic routing)
    assert res.comm_tuples == predicted_comm(plan)
    assert res.total_comm == sum(predicted_comm(plan).values())


def test_3way_paper_query_matches_oracle():
    data = paper_3way(np.random.default_rng(2), n=500, domain=300)
    plan = plan_shares_skew(three_way_paper(), data, q=150)
    res = _check(three_way_paper(), data, plan)
    assert res.count > 0


def test_triangle_matches_oracle():
    rng = np.random.default_rng(3)
    data = random_join_data(rng, triangle(), n_per_relation=300, domain=40)
    plan = plan_shares_skew(triangle(), data, q=200)
    _check(triangle(), data, plan)


def test_no_skew_single_residual():
    rng = np.random.default_rng(4)
    q = two_way()
    data = random_join_data(rng, q, n_per_relation=1000, domain=5000)
    plan = plan_shares_skew(q, data, q=300)
    assert len(plan.residuals) == 1
    _check(q, data, plan)


def test_plain_shares_correct_but_skewed():
    # Shares (no HH handling) still computes the right answer; its max load
    # explodes under skew — exactly the paper's Figure 3 observation.
    data = paper_2way(np.random.default_rng(5), n_r=3000, n_s=600, domain=2000)
    plain = plan_plain_shares(two_way(), data, k=64)
    res = run_join(two_way(), data, plain, cap_factor=40.0)
    count, checksum, _, _ = oracle_join(two_way(), data)
    assert res.overflow == 0
    assert (res.count, res.checksum) == (count, checksum)
    skew_plan = plan_shares_skew(two_way(), data, q=200)
    res_skew = run_join(two_way(), data, skew_plan, cap_factor=4.0)
    assert res_skew.load_imbalance < res.load_imbalance


def test_empty_relation():
    q = two_way()
    data = {
        "R": np.zeros((0, 2), dtype=np.int64),
        "S": np.array([[1, 2], [3, 4]], dtype=np.int64),
    }
    plan = plan_shares_skew(q, data, q=100)
    res = run_join(q, data, plan)
    assert res.count == 0


def test_all_tuples_one_value():
    # 100% skew (§9.3: "we only include tuples with one HH")
    q = two_way()
    n = 400
    rng = np.random.default_rng(6)
    data = {
        "R": np.stack([rng.integers(0, 1000, n), np.full(n, 7)], 1).astype(np.int64),
        "S": np.stack([np.full(n, 7), rng.integers(0, 1000, n)], 1).astype(np.int64),
    }
    plan = plan_shares_skew(q, data, q=100)
    res = _check(q, data, plan, cap_factor=6.0)
    assert res.count == n * n  # full cartesian product on B=7
    # Example 2's rectangle: load spread across reducers, none holds r+s
    assert res.max_load < 2 * n


# ------------------------------------------------------------ naive baseline
def test_naive_costs_more_than_shares_skew():
    # NB: for k <= r/s the optimal rectangle degenerates to x=k, y=1 — i.e.
    # the naive partition-broadcast IS optimal there and costs tie.  q=100
    # forces k > r_hh/s_hh (= 10), where 2*sqrt(krs) < r + k*s strictly.
    data = paper_2way(np.random.default_rng(7), n_r=20000, n_s=2000, domain=30000)
    plan = plan_shares_skew(two_way(), data, q=100)
    res = run_join(two_way(), data, plan, cap_factor=4.0)
    hh_res = next(r for r in plan.residuals if r.combo.pinned)
    k = hh_res.num_reducers
    stats = naive_two_way(
        data["R"], data["S"], np.array([7]), k_hh=k,
        k_ord=max(1, plan.total_reducers - k),
    )
    assert res.total_comm < stats.comm_tuples


# ------------------------------------------------------- distributed shuffle
_DISTRIBUTED_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.core import plan_shares_skew, two_way, three_way_paper
from repro.data import paper_2way, paper_3way
from repro.mapreduce import oracle_join, run_distributed

data = paper_2way(np.random.default_rng(0), n_r=3000, n_s=600, domain=2000)
plan = plan_shares_skew(two_way(), data, q=200)
res = run_distributed(two_way(), data, plan, cap_factor=4.0, route_cap_factor=4.0)
count, checksum, _, _ = oracle_join(two_way(), data)
assert res.overflow == 0, res.overflow
assert res.count == count, (res.count, count)
assert res.checksum == checksum, (res.checksum, checksum)

data3 = paper_3way(np.random.default_rng(2), n=400, domain=300)
plan3 = plan_shares_skew(three_way_paper(), data3, q=150)
res3 = run_distributed(three_way_paper(), data3, plan3, cap_factor=4.0, route_cap_factor=4.0)
c3, s3, _, _ = oracle_join(three_way_paper(), data3)
assert res3.overflow == 0
assert (res3.count, res3.checksum) == (c3, s3), ((res3.count, res3.checksum), (c3, s3))
print("DISTRIBUTED_OK")
"""


def test_distributed_shuffle_8_devices():
    """Real all_to_all over 8 host devices, in a subprocess so the main
    test process keeps its single-device view."""
    proc = subprocess.run(
        [sys.executable, "-c", _DISTRIBUTED_SNIPPET],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "DISTRIBUTED_OK" in proc.stdout


def test_distributed_single_device_matches_oracle():
    data = paper_2way(np.random.default_rng(8), n_r=2000, n_s=400, domain=1500)
    plan = plan_shares_skew(two_way(), data, q=200)
    from repro.mapreduce import run_distributed

    res = run_distributed(two_way(), data, plan, cap_factor=4.0)
    count, checksum, _, _ = oracle_join(two_way(), data)
    assert res.overflow == 0
    assert (res.count, res.checksum) == (count, checksum)


def test_speculative_join_matches_plain():
    """Over-decomposed reduce with speculative re-execution returns exactly
    the same (count, checksum, comm) as the monolithic run."""
    from repro.mapreduce import run_join_speculative

    data = paper_3way(np.random.default_rng(9), n=400, domain=300)
    plan = plan_shares_skew(three_way_paper(), data, q=120)
    base = run_join(three_way_paper(), data, plan, cap_factor=4.0)
    spec = run_join_speculative(
        three_way_paper(), data, plan, cap_factor=4.0, n_shards=3
    )
    assert spec.count == base.count
    assert spec.checksum == base.checksum
    assert spec.comm_tuples == base.comm_tuples
    assert spec.overflow == 0
