"""§Roofline: three-term roofline per (arch x shape x mesh) from the dry-run
artifacts (brief deliverable (g)).

  compute_s    = per-device FLOPs / 197e12      (v5e bf16 peak per chip)
  memory_s     = per-device HBM bytes / 819e9   (HBM bandwidth)
  collective_s = per-device wire bytes / 50e9   (~ICI link bandwidth)

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) for train; 2*N*D_tokens
for prefill/decode.  The ratio MODEL_FLOPS / (HLO flops x chips) exposes
remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def model_flops(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    spec = SHAPES[shape]
    n = cfg.n_active_params() if cfg.n_experts else cfg.n_params()
    if spec.kind == "train":
        tokens = spec.global_batch * spec.seq_len
        return 6.0 * n * tokens
    if spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * spec.global_batch  # decode: one token per sequence


def load_records(mesh: str = "pod16x16") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, f"{mesh}__*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    chips = rec["n_devices"]
    compute_s = rec["flops"] / PEAK_FLOPS
    memory_s = rec["hbm_bytes"] / HBM_BW
    collective_s = rec["collective_wire_bytes"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = rec["flops"] * chips
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        # fraction of roofline: ideal step time (compute term at the model's
        # useful flops) over the bound given by the dominant term
        "roofline_fraction": (mf / chips / PEAK_FLOPS) / bound if bound else 0.0,
    }


def build_table(mesh: str = "pod16x16") -> list[dict]:
    rows = []
    for rec in load_records(mesh):
        row = roofline_row(rec)
        if row:
            rows.append(row)
        elif rec.get("status") == "skipped":
            rows.append({
                "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
                "skipped": rec["reason"],
            })
    return rows


def render_markdown(rows: list[dict]) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "skipped" in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(lines)


def main() -> None:
    from .common import emit

    rows = build_table("pod16x16")
    ok = [r for r in rows if "skipped" not in r]
    if not ok:
        emit("roofline_rows", 0, "no dry-run artifacts yet; run repro.launch.dryrun")
        return
    for r in ok:
        emit(
            f"roofline_{r['arch']}_{r['shape']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"dom={r['dominant']};frac={r['roofline_fraction']:.3f};"
            f"useful={r['useful_ratio']:.2f}",
        )
    worst = min(ok, key=lambda r: r["roofline_fraction"])
    emit("roofline_worst_cell", worst["roofline_fraction"],
         f"{worst['arch']}/{worst['shape']} dom={worst['dominant']}")


if __name__ == "__main__":
    main()
