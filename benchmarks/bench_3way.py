"""Paper §9.2 / Figure 3: 3-way join R(A,B) ⋈ S(B,E,C) ⋈ T(C,D).

B has two heavy hitters, C one (10% of input) — Example 5's six residual
joins.  Compares: (a) plain Shares on skewed data (max reducer load blows
up — the out-of-scale bar in Fig 3), (b) SharesSkew on the same data,
(c) plain Shares on skew-free data (the paper's reference point: SharesSkew
on skewed data should approach it).
"""
from __future__ import annotations

import numpy as np

from repro.core import plan_plain_shares, plan_shares_skew, three_way_paper
from repro.data import paper_3way, random_join_data
from repro.mapreduce import oracle_join, run_join
from repro.mapreduce.executor import measure_loads

from .common import emit, time_call


def main() -> None:
    rng = np.random.default_rng(2)
    q = three_way_paper()
    skewed = paper_3way(rng, n=1_000, domain=10_000)
    clean = random_join_data(
        np.random.default_rng(3), q, n_per_relation=1_000, domain=10_000
    )
    q_cap = 80.0

    # hh_threshold below q: B's two HHs carry ~50 tuples each (10% of 1000
    # split two ways) — the paper's Ex. 5 setup detects all three HHs
    plan_skew = plan_shares_skew(q, skewed, q=q_cap, hh_threshold=40)
    res_skew = run_join(q, skewed, plan_skew, cap_factor=3.0)
    c, s, _, _ = oracle_join(q, skewed)
    assert (res_skew.count, res_skew.checksum) == (c, s)
    assert res_skew.overflow == 0

    # plain Shares on skewed data: measure the load skew via the map phase
    # only (materializing its reducers would need ~100x capacity — that IS
    # the pathology the paper fixes)
    plan_plain = plan_plain_shares(q, skewed, k=plan_skew.total_reducers)
    res_plain = measure_loads(q, skewed, plan_plain)

    plan_clean = plan_plain_shares(q, clean, k=plan_skew.total_reducers)
    res_clean = measure_loads(q, clean, plan_clean)

    emit("3way_residual_joins", len(plan_skew.residuals), "paper Ex.5: expects 6")
    emit("3way_sharesskew_max_load", res_skew.max_load,
         f"imbalance={res_skew.load_imbalance:.2f};comm={res_skew.total_comm}")
    emit("3way_plain_shares_skewed_max_load", res_plain.max_load,
         f"imbalance={res_plain.load_imbalance:.2f};comm={res_plain.total_comm}")
    emit("3way_plain_shares_clean_max_load", res_clean.max_load,
         f"imbalance={res_clean.load_imbalance:.2f}")
    # the paper's headline: SharesSkew-on-skew ~ Shares-on-clean
    emit("3way_skew_mitigation_ratio",
         res_plain.max_load / max(res_skew.max_load, 1),
         "plain/SharesSkew max-load; >1 means SharesSkew wins (Fig 3)")
    t_us = time_call(lambda: run_join(q, skewed, plan_skew, cap_factor=3.0))
    emit("3way_engine_wall", t_us, f"count={res_skew.count}")


if __name__ == "__main__":
    main()
