"""Streaming SharesSkew (DESIGN.md §6): drifting Zipf stream, drift-triggered
replanning, comm vs an exact-HH replan-every-batch oracle.

The workload shifts the Zipf mode of the join attribute mid-run.  Tracked:

  * cumulative new-tuple shuffle volume of the streaming engine vs the
    oracle that replans each batch from exact heavy hitters (the acceptance
    target is a ratio <= 1.25);
  * number of drift-triggered replans and migrated state;
  * per-batch ingest wall time.

Also writes ``BENCH_stream.json`` next to the repo root so the perf
trajectory of the streaming path is recorded run over run.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from repro.core import plan_shares_skew, two_way
from repro.mapreduce import oracle_join, predicted_comm
from repro.stream import StreamConfig, StreamingJoinEngine

from .common import emit


def _zipf_batch(rng, shift, n_r, n_s, domain, a=1.6):
    b_r = ((rng.zipf(a, n_r) - 1) + shift) % domain
    b_s = ((rng.zipf(a, n_s) - 1) + shift) % domain
    r = np.stack([rng.integers(0, domain, n_r), b_r], 1).astype(np.int64)
    s = np.stack([b_s, rng.integers(0, domain, n_s)], 1).astype(np.int64)
    return {"R": r, "S": s}


def main(out_json: str | None = "BENCH_stream.json") -> None:
    rng = np.random.default_rng(0)
    query = two_way()
    n_r, n_s, domain = 1500, 400, 4000
    n_batches, shift_at = 8, 4

    eng = StreamingJoinEngine(
        query, StreamConfig(q=120, decay=0.5, load_factor=2.0)
    )
    oracle_comm = 0
    ingest_us = []
    for i in range(n_batches):
        # the drift: both the Zipf exponent and the heavy values' location
        # shift mid-run
        shift, a = (0, 2.0) if i < shift_at else (1300, 1.4)
        batch = _zipf_batch(rng, shift, n_r, n_s, domain, a=a)
        t0 = time.perf_counter()
        eng.ingest(batch)
        ingest_us.append((time.perf_counter() - t0) * 1e6)
        oracle_plan = plan_shares_skew(query, batch, q=120)
        oracle_comm += sum(predicted_comm(oracle_plan).values())

    count, checksum, _, _ = oracle_join(query, eng.history_data())
    assert (eng.total_count, eng.total_checksum) == (count, checksum), (
        "streaming engine != concatenated oracle"
    )
    ratio = eng.cumulative_comm / max(1, oracle_comm)
    assert ratio <= 1.25, f"comm ratio {ratio:.3f} exceeds 1.25x oracle"
    assert eng.replan_count >= 1, "no drift replan fired on the shifted stream"

    med_us = sorted(ingest_us)[len(ingest_us) // 2]
    emit("stream_comm_ratio_vs_oracle", ratio * 1000,
         f"engine={eng.cumulative_comm};oracle={oracle_comm};x1000")
    emit("stream_replans", eng.replan_count,
         f"migrated={eng.total_migrated};epochs={eng.plan_epoch + 1}")
    emit("stream_ingest_wall", med_us,
         f"batches={n_batches};total_count={eng.total_count}")

    if out_json:
        record = {
            "bench": "stream",
            "batches": n_batches,
            "rows_per_batch": {"R": n_r, "S": n_s},
            "comm_ratio_vs_oracle": ratio,
            "engine_comm": eng.cumulative_comm,
            "oracle_comm": oracle_comm,
            "replans": eng.replan_count,
            "migrated_tuples": eng.total_migrated,
            "median_ingest_us": med_us,
            "total_count": eng.total_count,
            "replan_reasons": [
                r.drift_reason for r in eng.reports if r.replanned and r.batch > 0
            ],
        }
        path = pathlib.Path(out_json)
        path.write_text(json.dumps(record, indent=2) + "\n")
        print(f"# wrote {path}")


if __name__ == "__main__":
    main()
