"""Streaming SharesSkew (DESIGN.md §6-§7): drifting Zipf stream, drift-
triggered replanning, fused vs baseline ingest.

The workload shifts the Zipf mode of the join attribute mid-run.  Two
engines consume the *same* pre-generated batches:

  * baseline — sketch, ``map_phase`` routing, and the einsum delta join as
    separate eager passes (the correctness oracle);
  * fused    — the single-pass Pallas ingest kernel (``kernels.
    ingest_fused``) plus the sorted merge-join delta (DESIGN.md §7).

Tracked:

  * cumulative new-tuple shuffle volume vs an exact-HH replan-every-batch
    oracle (acceptance: ratio <= 1.25, identical for both engines);
  * number of drift-triggered replans and migrated state;
  * per-batch ingest wall time for both paths, the fused speedup (hard
    gate: fused median must be >= 10x faster than the 852 ms baseline
    median recorded at PR 5), and the modeled DMA/compute overlap profile
    of the fused kernel;
  * bounded state (DESIGN.md §8): a third engine runs the same batches
    under windowed retention + admission accounting — peak carried state
    must drop below the unbounded engine's, the window fingerprint must
    equal the oracle on the retained suffix, and the retention/shed
    counters land in the ``bounded`` sub-record;
  * reducer-loss recovery (DESIGN.md §5): a fourth engine runs with the
    host model on and one host killed mid-run by the fault injector —
    recovery must complete at the kill boundary itself (no checkpoint
    restore), replay no more than the lost reducers' retained-window
    share, and verify the window fingerprint; the ``recovery`` sub-record
    tracks the boundary wall time and replay volume;
  * replan boundaries (DESIGN.md §7): the dense route encoding keeps the
    fused kernel's padded shapes static across replans, so a replan batch
    must NOT pay a kernel recompile — ``replan_compile_us`` records the
    replan-boundary overhead over the steady-state median (planning +
    migration only), with a hard 1 s ceiling per replan batch;
  * multi-tenant (DESIGN.md §9): a ``MultiQueryEngine`` runs 3 copies of
    the query over the same batches — every tenant must stay bit-identical
    to the solo run with ZERO private sketch passes (the shared pass runs
    once per relation batch), and a weighted fair-share run with an
    injected overload burst must shed ONLY the offending tenant; the
    ``tenancy`` sub-record tracks isolation overhead vs N separate
    engines, sketch-sharing savings, and the per-tenant shed counters;
  * observability (DESIGN.md §10): a fifth engine repeats the fused run
    with tracing + metrics + skewscope all on — it must stay bit-identical
    to the plain fused run and its median ingest overhead must stay under
    2% (``obs.overhead_pct`` in the sub-record, alongside the span
    taxonomy, the per-reducer skew snapshot, and the replan triggers).

``BENCH_stream.json`` (all fields documented in BENCHMARKS.md) records the
trajectory run over run.  The fused engine counts its kernel passes; this
bench fails loudly if that counter ever disagrees with the batch count —
there is no silent fallback path, and this assertion keeps it that way.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from repro.core import plan_shares_skew, two_way
from repro.kernels.ingest_fused import overlap_profile, route_width
from repro.mapreduce import oracle_join, predicted_comm
from repro.mapreduce.keys import static_route_table
from repro.stream import (
    AdmissionPolicy,
    MultiQueryEngine,
    RecoveryPolicy,
    RetentionPolicy,
    ObsPolicy,
    StreamConfig,
    StreamingJoinEngine,
    TenancyPolicy,
    TenantSpec,
    replication_width,
)
from repro.testing import FaultInjector, FaultSpec

from .common import emit

# the bench-host gate: PR 5 recorded median_ingest_us = 852574 on this
# workload; the fused path must beat it by >= 10x
RECORDED_BASELINE_US = 852_574.0
FUSED_GATE_US = RECORDED_BASELINE_US / 10.0


def _zipf_batch(rng, shift, n_r, n_s, domain, a=1.6):
    b_r = ((rng.zipf(a, n_r) - 1) + shift) % domain
    b_s = ((rng.zipf(a, n_s) - 1) + shift) % domain
    r = np.stack([rng.integers(0, domain, n_r), b_r], 1).astype(np.int64)
    s = np.stack([b_s, rng.integers(0, domain, n_s)], 1).astype(np.int64)
    return {"R": r, "S": s}


def _median(xs: list[float]) -> float:
    return sorted(xs)[len(xs) // 2]


def main(out_json: str | None = "BENCH_stream.json") -> None:
    rng = np.random.default_rng(0)
    query = two_way()
    n_r, n_s, domain = 1500, 400, 4000
    n_batches, shift_at = 8, 4
    rows_per_batch = n_r + n_s

    # identical batches for both engines: the drift moves both the Zipf
    # exponent and the heavy values' location mid-run
    batches = []
    for i in range(n_batches):
        shift, a = (0, 2.0) if i < shift_at else (1300, 1.4)
        batches.append(_zipf_batch(rng, shift, n_r, n_s, domain, a=a))

    def run(config: StreamConfig):
        eng = StreamingJoinEngine(query, config)
        us = []
        for batch in batches:
            t0 = time.perf_counter()
            eng.ingest(batch)
            us.append((time.perf_counter() - t0) * 1e6)
        return eng, us

    base, base_us = run(StreamConfig(q=120, decay=0.5, load_factor=2.0))
    fused, fused_us = run(
        StreamConfig(q=120, decay=0.5, load_factor=2.0, fused_ingest=True)
    )

    # ---- correctness gates -------------------------------------------------
    count, checksum, _, _ = oracle_join(query, base.history_data())
    assert (base.total_count, base.total_checksum) == (count, checksum), (
        "streaming engine != concatenated oracle"
    )
    assert (fused.total_count, fused.total_checksum) == (count, checksum), (
        "fused engine != concatenated oracle"
    )
    for i, (rb, rf) in enumerate(zip(base.reports, fused.reports)):
        assert rb == rf, f"fused batch {i} report diverges from baseline"
    assert fused.fused_batches == n_batches, (
        f"fused engine ran the kernel on {fused.fused_batches}/{n_batches} "
        "batches — the fused path silently fell back"
    )

    oracle_comm = 0
    for batch in batches:
        oracle_plan = plan_shares_skew(query, batch, q=120)
        oracle_comm += sum(predicted_comm(oracle_plan).values())
    ratio = base.cumulative_comm / max(1, oracle_comm)
    assert ratio <= 1.25, f"comm ratio {ratio:.3f} exceeds 1.25x oracle"
    assert base.replan_count >= 1, "no drift replan fired on the shifted stream"

    # ---- perf gate ---------------------------------------------------------
    base_med, fused_med = _median(base_us), _median(fused_us)
    speedup = base_med / fused_med
    assert fused_med < FUSED_GATE_US, (
        f"fused median ingest {fused_med / 1e3:.1f} ms misses the 10x gate "
        f"({FUSED_GATE_US / 1e3:.1f} ms) vs the recorded "
        f"{RECORDED_BASELINE_US / 1e3:.0f} ms baseline"
    )

    # replan boundaries: with the dense route encoding the compiled kernel
    # survives replans, so a replan batch is planning + migration only —
    # not the multi-second recompile spike PR 8's BENCH_stream recorded
    replan_ix = [
        i for i, r in enumerate(fused.reports) if r.replanned and i > 0
    ]
    steady_us = [
        u for i, u in enumerate(fused_us) if i > 0 and i not in replan_ix
    ]
    steady_med = _median(steady_us)
    replan_compile_us = (
        max(0.0, _median([fused_us[i] for i in replan_ix]) - steady_med)
        if replan_ix
        else 0.0
    )
    for i in replan_ix:
        assert fused_us[i] < 1_000_000, (
            f"replan batch {i} took {fused_us[i] / 1e3:.0f} ms — the fused "
            "kernel recompiled at a replan boundary"
        )

    # ---- observability overhead (DESIGN.md §10) ----------------------------
    # the same fused run with every obs surface on (tracing + metrics +
    # skewscope).  The kernels are warm by now (identical shapes), so the
    # median delta over the plain fused run is the obs tax itself — gated
    # at < 2% so the layer stays always-on-able
    obs_eng, obs_us = run(
        StreamConfig(
            q=120, decay=0.5, load_factor=2.0, fused_ingest=True,
            obs=ObsPolicy(trace=True, metrics=True, skewscope=True),
        )
    )
    assert (obs_eng.total_count, obs_eng.total_checksum) == (count, checksum), (
        "obs-enabled engine diverged from the oracle — instrumentation "
        "touched the data path"
    )
    for i, (rf, ro) in enumerate(zip(fused.reports, obs_eng.reports)):
        assert rf == ro, f"obs-enabled batch {i} report diverges from fused"
    obs_med = _median(obs_us)
    obs_overhead_pct = (obs_med - fused_med) / fused_med * 100.0
    assert obs_overhead_pct < 2.0, (
        f"tracing+metrics added {obs_overhead_pct:.2f}% to the fused median "
        "ingest — the observability layer is no longer cheap"
    )
    chrome = obs_eng.obs.tracer.to_chrome()
    skew_snapshot = obs_eng.skew_report()
    obs_metrics = obs_eng.obs.metrics.snapshot()
    replan_triggers = [
        {
            "batch": r.batch,
            "trigger": r.drift_trigger,
            "observed": r.drift_observed,
            "threshold": r.drift_threshold,
        }
        for r in obs_eng.reports
        if r.replanned
    ]

    # ---- bounded state (DESIGN.md §8) --------------------------------------
    # same batches under windowed retention + admission: carried state must
    # flatten (vs the unbounded engine's monotonic growth) and the window
    # fingerprint must stay exact on the retained suffix
    bounded, _ = run(
        StreamConfig(
            q=120, decay=0.5, load_factor=2.0, fused_ingest=True,
            retention=RetentionPolicy(window_batches=3),
            admission=AdmissionPolicy(headroom=50.0),  # accounting on, no throttle
        )
    )
    w_count, w_checksum, _, _ = oracle_join(query, bounded.history_data())
    assert (bounded.window_count, bounded.window_checksum) == (
        w_count, w_checksum,
    ), "bounded engine window fingerprint != oracle on retained suffix"
    assert bounded.expired_batches == n_batches - 3
    peak_carried_bounded = max(r.carried_tuples for r in bounded.reports)
    peak_carried_unbounded = max(r.carried_tuples for r in base.reports)
    assert peak_carried_bounded < peak_carried_unbounded, (
        "retention failed to bound carried state"
    )

    # ---- reducer-loss recovery (DESIGN.md §5) ------------------------------
    # same batches again with the host model on and a host killed mid-run:
    # recovery must run at the batch boundary (no checkpoint restore),
    # replay exactly the lost reducers' retained-window share, and keep
    # the window fingerprint exact
    kill_batch = shift_at + 1
    inj = FaultInjector(
        [FaultSpec(kind="host_loss", target="host", host_id=2,
                   batch=kill_batch)]
    )
    rec_eng = StreamingJoinEngine(
        query,
        StreamConfig(
            q=120, decay=0.5, load_factor=2.0,
            retention=RetentionPolicy(window_batches=3),
            recovery=RecoveryPolicy(n_hosts=8),
        ),
    )
    rec_eng.arm_faults(inj)
    recovery_us = 0.0
    for i, batch in enumerate(batches):
        t0 = time.perf_counter()
        rec_eng.ingest(batch)
        if i == kill_batch:  # the boundary that detected + recovered
            recovery_us = (time.perf_counter() - t0) * 1e6
    inj.assert_all_resolved()
    assert len(rec_eng.recoveries) == 1, "host loss was not recovered"
    rec = rec_eng.recoveries[0]
    assert rec.verified, "recovered state failed fingerprint verification"
    assert rec.replayed_tuples <= rec.lost_share_tuples
    r_count, r_checksum, _, _ = oracle_join(query, rec_eng.history_data())
    assert (rec_eng.window_count, rec_eng.window_checksum) == (
        r_count, r_checksum,
    ), "post-recovery window fingerprint != oracle"

    # ---- multi-tenant (DESIGN.md §9) ---------------------------------------
    # 3 tenants over the same batches.  Reference: N separate engines (the
    # sharing-free deployment).  Contracts: every tenant bit-identical to
    # the solo run, zero private sketch passes, and the shared pass count
    # equals (sketch columns) x (batches) — computed once, absorbed N times.
    n_tenants = 3
    t_cfg = StreamConfig(q=120, decay=0.5, load_factor=2.0, fused_ingest=True)
    solo_runs = [run(t_cfg) for _ in range(n_tenants)]
    solo_engines = [e for e, _ in solo_runs]
    # per shared batch, the reference cost is the SUM over the N engines;
    # medians keep one-off compile spikes out of the overhead ratio (the
    # multi-tenant run compiles a sketch-off kernel variant on batch 0)
    solo_batch_us = [
        sum(us[i] for _, us in solo_runs) for i in range(n_batches)
    ]
    solo_wall_us = sum(solo_batch_us)
    solo_med_us = _median(solo_batch_us)
    solo_private_passes = sum(
        e.sketch_ingest_calls for e in solo_engines
    )

    mq = MultiQueryEngine(
        [TenantSpec(f"t{i}", query, t_cfg) for i in range(n_tenants)],
        TenancyPolicy(),
    )
    mq_batch_us = []
    for batch in batches:
        t0 = time.perf_counter()
        mq.ingest(batch)
        mq_batch_us.append((time.perf_counter() - t0) * 1e6)
    mq_wall_us = sum(mq_batch_us)
    mq_med_us = _median(mq_batch_us)
    for i in range(n_tenants):
        eng = mq.engine(f"t{i}")
        assert (eng.total_count, eng.total_checksum) == (count, checksum), (
            f"tenant t{i} diverged from the solo engine"
        )
        assert eng.sketch_ingest_calls == 0, (
            f"tenant t{i} computed {eng.sketch_ingest_calls} private sketch "
            "passes — sketch sharing silently fell back"
        )
    n_sketch_cols = 2  # (B, R) and (B, S): one shared signature group
    assert mq.shared_sketch_passes == n_sketch_cols * n_batches, (
        f"shared sketch ran {mq.shared_sketch_passes} column passes, "
        f"expected {n_sketch_cols * n_batches} (once per relation batch)"
    )
    isolation_overhead = mq_med_us / solo_med_us
    assert isolation_overhead < 1.5, (
        f"multi-tenant median batch {isolation_overhead:.2f}x the "
        "N-separate-engines reference — tenancy bookkeeping is no longer cheap"
    )

    # weighted fair-share under an injected overload burst: capacity is
    # raised operator-style to 1.5x the observed steady demand right before
    # the burst batch, so normal load fits and ONLY the burst is over
    overload_batch = shift_at + 2
    fmq = MultiQueryEngine(
        [
            TenantSpec(f"f{i}", query, t_cfg, weight=2.0 if i == 0 else 1.0)
            for i in range(n_tenants)
        ],
        TenancyPolicy(),
    )
    inj2 = FaultInjector(
        [FaultSpec(kind="tenant_overload", target="tenant", tenant="f2",
                   batch=overload_batch, rel="R", rows=6000)]
    )
    fmq.arm_faults(inj2)
    for i, batch in enumerate(batches):
        if i == overload_batch:
            demand = sum(
                len(batch[rel.name])
                * replication_width(fmq.engine(nm).plan, rel.name)
                for nm in fmq.serving()
                for rel in query.relations
            )
            fmq.fair.capacity = 1.5 * demand
        fmq.ingest(batch)
        if i == overload_batch:
            fmq.fair.capacity = None
    inj2.assert_all_resolved()
    shed = dict(fmq.fair.overload_shed)
    assert shed["f2"] > 0, "the overloaded tenant was never shed"
    assert shed["f0"] == 0 and shed["f1"] == 0, (
        f"overload on f2 shed a well-behaved neighbor: {shed}"
    )
    for nm in ("f0", "f1"):
        eng = fmq.engine(nm)
        assert (eng.total_count, eng.total_checksum) == (count, checksum), (
            f"tenant {nm} perturbed by f2's overload burst"
        )
    contained = inj2.report().contained

    # modeled roofline of the fused pass under the final plan (R relation)
    rel = query.relations[0]
    profile = overlap_profile(
        n_rows=n_r,
        arity=rel.arity,
        route_w=route_width(static_route_table(fused.plan, rel)),
        num_reducers=fused.plan.total_reducers,
        n_sketch_cols=1,
        depth=fused.config.sketch_depth,
        width=fused.config.sketch_width,
        block=fused.config.fused_block,
    )

    emit("stream_comm_ratio_vs_oracle", ratio * 1000,
         f"engine={base.cumulative_comm};oracle={oracle_comm};x1000")
    emit("stream_replans", base.replan_count,
         f"migrated={base.total_migrated};epochs={base.plan_epoch + 1}")
    emit("stream_ingest_wall", base_med,
         f"batches={n_batches};total_count={base.total_count}")
    emit("stream_fused_ingest_wall", fused_med,
         f"speedup={speedup:.1f}x;vs_recorded="
         f"{RECORDED_BASELINE_US / fused_med:.1f}x")
    emit("stream_bounded_peak_carried", peak_carried_bounded,
         f"unbounded={peak_carried_unbounded};"
         f"window={bounded.config.retention.window_batches};"
         f"expired={bounded.expired_batches}")
    emit("stream_bounded_shed", bounded.total_shed,
         f"deferred={bounded.total_deferred};"
         f"retracted={bounded.total_retracted}")
    emit("stream_recovery_wall", recovery_us,
         f"mode={rec.mode};replayed={rec.replayed_tuples};"
         f"lost_reducers={rec.lost_reducers};verified={rec.verified}")
    emit("stream_replan_compile", replan_compile_us,
         f"steady_median={steady_med:.0f}us;replans={len(replan_ix)}")
    emit("stream_obs_overhead", obs_overhead_pct * 1000,
         f"obs_median={obs_med:.0f}us;fused_median={fused_med:.0f}us;"
         f"spans={len(chrome['traceEvents'])};x1000")
    emit("stream_tenancy_overhead", isolation_overhead * 1000,
         f"tenants={n_tenants};shared_passes={mq.shared_sketch_passes};"
         f"private_avoided={solo_private_passes};x1000")
    emit("stream_tenancy_shed", shed["f2"],
         f"neighbors={shed['f0']}+{shed['f1']};contained={contained}")
    for i, (bu, fu) in enumerate(zip(base_us, fused_us)):
        replanned = base.reports[i].replanned
        print(f"# batch {i}: baseline {bu / 1e3:8.1f} ms  "
              f"fused {fu / 1e3:8.1f} ms"
              f"{'  [replan]' if replanned else ''}")

    if out_json:
        record = {
            "bench": "stream",
            "batches": n_batches,
            "rows_per_batch": {"R": n_r, "S": n_s},
            "comm_ratio_vs_oracle": ratio,
            "engine_comm": base.cumulative_comm,
            "oracle_comm": oracle_comm,
            "replans": base.replan_count,
            "migrated_tuples": base.total_migrated,
            # wall-clock AND per-row-normalized medians for both paths: the
            # per-row figures stay comparable if the workload shape changes
            "median_ingest_us": base_med,
            "median_ingest_ns_per_row": base_med * 1e3 / rows_per_batch,
            "fused_median_ingest_us": fused_med,
            "fused_median_ingest_ns_per_row": fused_med * 1e3 / rows_per_batch,
            "fused_speedup": speedup,
            "fused_speedup_vs_recorded": RECORDED_BASELINE_US / fused_med,
            "fused_batches": fused.fused_batches,
            # replan boundaries with the dense route encoding: overhead of
            # a replan batch over steady state (planning + migration; a
            # recompile here trips the 1 s assertion instead of landing
            # silently in this field)
            "fused_steady_median_us": steady_med,
            "replan_compile_us": replan_compile_us,
            "replan_batches": replan_ix,
            "ingest_us_trend": [
                {
                    "batch": i,
                    "baseline_us": bu,
                    "fused_us": fu,
                    "replanned": base.reports[i].replanned,
                }
                for i, (bu, fu) in enumerate(zip(base_us, fused_us))
            ],
            "overlap_profile": profile,
            "bounded": {
                "window_batches": bounded.config.retention.window_batches,
                "admission_headroom": bounded.config.admission.headroom,
                "peak_carried_tuples": peak_carried_bounded,
                "peak_carried_tuples_unbounded": peak_carried_unbounded,
                "final_carried_tuples": bounded.reports[-1].carried_tuples,
                "max_carried_per_reducer": max(
                    r.max_carried for r in bounded.reports
                ),
                "expired_batches": bounded.expired_batches,
                "retracted_results": bounded.total_retracted,
                "deferred_rows": bounded.total_deferred,
                "shed_rows": bounded.total_shed,
                "window_count": bounded.window_count,
                "window_fingerprint_verified": True,  # asserted above
            },
            "recovery": {
                "n_hosts": rec_eng.config.recovery.n_hosts,
                "kill_batch": kill_batch,
                "mode": rec.mode,
                "lost_hosts": list(rec.lost_hosts),
                "lost_reducers": rec.lost_reducers,
                "batches_to_recover": 1,  # detected + repaired at the
                #                           kill boundary itself
                "batches_replayed": rec.batches_replayed,
                "replayed_tuples": rec.replayed_tuples,
                "lost_share_tuples": rec.lost_share_tuples,
                "recovery_boundary_us": recovery_us,
                "survivors": rec.survivors,
                "fingerprint_verified": rec.verified,  # also asserted above
            },
            "tenancy": {
                "tenants": n_tenants,
                "isolation_overhead": isolation_overhead,
                "mq_median_batch_us": mq_med_us,
                "solo_median_batch_us": solo_med_us,
                "mq_wall_us": mq_wall_us,
                "solo_wall_us": solo_wall_us,
                "shared_sketch_passes": mq.shared_sketch_passes,
                "private_sketch_passes_avoided": solo_private_passes,
                "tenants_bit_identical": True,  # asserted above
                "overload_batch": overload_batch,
                "overload_shed_rows": shed,
                "fair_weights": {"f0": 2.0, "f1": 1.0, "f2": 1.0},
                "contained_faults": contained,
            },
            "obs": {
                # overhead of trace+metrics+skewscope over the plain fused
                # run (same warm kernels) — gated < 2% above
                "overhead_pct": obs_overhead_pct,
                "obs_median_ingest_us": obs_med,
                "fused_median_ingest_us": fused_med,
                "trace_events": len(chrome["traceEvents"]),
                "span_names": sorted(obs_eng.obs.tracer.span_names()),
                "metric_series": {
                    kind: len(series)
                    for kind, series in obs_metrics.items()
                },
                "skew": skew_snapshot.as_dict(),
                "replan_triggers": replan_triggers,
            },
            "total_count": base.total_count,
            "replan_reasons": [
                r.drift_reason for r in base.reports if r.replanned and r.batch > 0
            ],
        }
        path = pathlib.Path(out_json)
        path.write_text(json.dumps(record, indent=2) + "\n")
        print(f"# wrote {path}")


if __name__ == "__main__":
    main()
