"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_call(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall-time of fn(*args) in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
