"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus section banners on
stderr-free stdout comments).  Mapping to the paper:

  bench_2way           -> Fig 1(a)/(b)  (naive vs SharesSkew, 2-way)
  bench_2way_scaling   -> Fig 2         (shuffle volume ~ 2*sqrt(krs))
  bench_3way           -> Fig 3 / §9.2  (Shares vs SharesSkew, 3-way)
  bench_closed_forms   -> §8.1-8.3, §7.3 (chains, symmetric, lower bound)
  bench_moe_skew       -> beyond-paper  (SharesSkew expert dispatch)
  bench_stream         -> beyond-paper  (streaming engine, BENCH_stream.json)
  roofline             -> §Roofline     (from dry-run artifacts)
"""
from __future__ import annotations

import traceback


def main() -> None:
    from . import (
        bench_2way,
        bench_2way_scaling,
        bench_3way,
        bench_closed_forms,
        bench_moe_skew,
        bench_stream,
        roofline,
    )

    print("name,us_per_call,derived")
    failures = []
    for mod in (
        bench_2way,
        bench_2way_scaling,
        bench_3way,
        bench_closed_forms,
        bench_moe_skew,
        bench_stream,
        roofline,
    ):
        name = mod.__name__.split(".")[-1]
        print(f"# --- {name} ---")
        try:
            mod.main()
        except Exception:
            failures.append(name)
            print(f"# {name} FAILED")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
