"""Paper §8 (chain + symmetric joins) and §7.3 (lower bound): closed forms
vs the numeric geometric-program solver."""
from __future__ import annotations

import math

from repro.core import (
    chain_cost,
    chain_cost_equal_sizes,
    chain_join,
    solve_shares,
    subchain_budgets,
    symmetric_cost,
    symmetric_cost_equal_sizes,
    symmetric_join,
    two_way,
    two_way_lower_bound,
    two_way_skew_cost,
)

from .common import emit


def main() -> None:
    # chains (§8.1-8.2)
    for n, k in ((4, 256), (6, 4096), (8, 1 << 14)):
        q = chain_join(n)
        sizes = {f"R{i+1}": 1e5 for i in range(n)}
        sol = solve_shares(q, sizes, k)
        cf = chain_cost_equal_sizes(n, 1e5, k)
        emit(f"chain{n}_cost_solver", sol.cost,
             f"closed_form={cf:.4e};rel_err={abs(sol.cost-cf)/cf:.2e}")
    sizes_list = [2e5, 1e5, 3e5, 1.5e5]
    q4 = chain_join(4)
    sol = solve_shares(q4, {f"R{i+1}": s for i, s in enumerate(sizes_list)}, 4096)
    cf = chain_cost(sizes_list, 4096)
    emit("chain4_arbitrary_sizes", sol.cost, f"closed_form={cf:.4e}")

    # sub-chain reducer budgets with HHs (§8.1)
    ks = subchain_budgets([4, 6], 1 << 16)
    emit("chain_hh_subchain_budgets", ks[0], f"k2={ks[1]:.1f};prod={ks[0]*ks[1]:.0f}")

    # symmetric joins (§8.3 Thm 2)
    for n, d in ((4, 2), (5, 3), (6, 4), (6, 5)):
        q = symmetric_join(n, d)
        sizes = {f"R{j+1}": 1e5 for j in range(n)}
        k = 4096
        sol = solve_shares(q, sizes, k)
        cf = symmetric_cost(n, d, [1e5] * n, k)
        emit(f"symmetric_n{n}_d{d}_cost", sol.cost,
             f"thm2={cf:.4e};rel_err={abs(sol.cost-cf)/cf:.2e}")
    # skew-resilience claim: cost ∝ k^{1-d/n} shrinks as d -> n
    c_low = symmetric_cost_equal_sizes(6, 2, 1e5, 4096)
    c_high = symmetric_cost_equal_sizes(6, 5, 1e5, 4096)
    emit("symmetric_resilience_ratio", c_low / c_high, "k^(1-2/6) vs k^(1-5/6)")

    # 2-way lower bound (§7.3): achieved == bound
    r, s, k = 1e6, 1e5, 256
    emit("2way_lower_bound_gap",
         two_way_skew_cost(r, s, k) / two_way_lower_bound(r, s, k),
         "achieved/bound == 1.0 (optimal)")


if __name__ == "__main__":
    main()
