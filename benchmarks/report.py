"""Render the §Roofline tables from dry-run artifacts.

  python -m benchmarks.report                      # print single-pod table
  python -m benchmarks.report --mesh pod2x16x16    # multi-pod table
  python -m benchmarks.report --write-experiments  # splice into EXPERIMENTS.md
"""
from __future__ import annotations

import argparse
import os
import re

from .roofline import build_table, render_markdown

_MARK = "<!-- ROOFLINE_TABLE -->"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--write-experiments", action="store_true")
    args = ap.parse_args()

    md = render_markdown(build_table(args.mesh))
    if not args.write_experiments:
        print(md)
        return
    path = os.path.join(os.path.dirname(os.path.dirname(__file__)), "EXPERIMENTS.md")
    text = open(path).read()
    if _MARK in text:
        # replace marker (and any previously spliced table right after it)
        pattern = re.escape(_MARK) + r"(\n\|.*?(?:\n\|.*?)*)?"
        text = re.sub(pattern, _MARK + "\n" + md, text, count=1)
        open(path, "w").write(text)
        print(f"wrote roofline table ({args.mesh}) into EXPERIMENTS.md")
    else:
        print("marker not found in EXPERIMENTS.md")


if __name__ == "__main__":
    main()
