"""Render markdown tables from dry-run artifacts.

  python -m benchmarks.report                      # print single-pod roofline
  python -m benchmarks.report --mesh pod2x16x16    # multi-pod roofline
  python -m benchmarks.report --write-experiments  # splice into EXPERIMENTS.md
  python -m benchmarks.report --stream             # BENCH_stream.json tables

``--stream`` renders the streaming bench record (BENCHMARKS.md schema):
the headline trajectory plus every sub-record — ``bounded`` (§8),
``recovery`` (§5), ``tenancy`` (§9), and ``obs`` (§10, the observability
overhead table with the span taxonomy and per-reducer skew snapshot).
"""
from __future__ import annotations

import argparse
import json
import os
import re

from .roofline import build_table, render_markdown

_MARK = "<!-- ROOFLINE_TABLE -->"


def _table(title: str, rows: list[tuple[str, object]]) -> str:
    """One two-column markdown table with a bolded section header."""
    out = [f"**{title}**", "", "| metric | value |", "|---|---|"]
    out += [f"| {k} | {v} |" for k, v in rows]
    return "\n".join(out)


def _fmt(v: object) -> str:
    if isinstance(v, float):
        return f"{v:,.2f}"
    if isinstance(v, int):
        return f"{v:,}"
    return str(v)


def render_stream(record: dict) -> str:
    """Markdown for one BENCH_stream.json record: headline + sub-records."""
    sections = [
        _table("Streaming ingest (DESIGN.md §6-§7)", [
            ("batches", _fmt(record["batches"])),
            ("comm ratio vs oracle", _fmt(record["comm_ratio_vs_oracle"])),
            ("replans", _fmt(record["replans"])),
            ("migrated tuples", _fmt(record["migrated_tuples"])),
            ("baseline median ingest (us)", _fmt(record["median_ingest_us"])),
            ("fused median ingest (us)",
             _fmt(record["fused_median_ingest_us"])),
            ("fused speedup", _fmt(record["fused_speedup"])),
            ("replan-boundary overhead (us)",
             _fmt(record["replan_compile_us"])),
        ]),
    ]
    if "bounded" in record:
        b = record["bounded"]
        sections.append(_table("Bounded state (§8)", [
            ("window (batches)", _fmt(b["window_batches"])),
            ("peak carried tuples", _fmt(b["peak_carried_tuples"])),
            ("peak carried (unbounded)",
             _fmt(b["peak_carried_tuples_unbounded"])),
            ("expired batches", _fmt(b["expired_batches"])),
            ("retracted results", _fmt(b["retracted_results"])),
            ("deferred rows", _fmt(b["deferred_rows"])),
            ("shed rows", _fmt(b["shed_rows"])),
            ("window fingerprint verified",
             b["window_fingerprint_verified"]),
        ]))
    if "recovery" in record:
        r = record["recovery"]
        sections.append(_table("Reducer-loss recovery (§5)", [
            ("hosts", _fmt(r["n_hosts"])),
            ("kill batch / mode", f"{r['kill_batch']} / {r['mode']}"),
            ("lost reducers", _fmt(r["lost_reducers"])),
            ("replayed tuples", _fmt(r["replayed_tuples"])),
            ("lost-share tuples", _fmt(r["lost_share_tuples"])),
            ("recovery boundary (us)", _fmt(r["recovery_boundary_us"])),
            ("survivors", _fmt(r["survivors"])),
            ("fingerprint verified", r["fingerprint_verified"]),
        ]))
    if "tenancy" in record:
        t = record["tenancy"]
        shed = ", ".join(
            f"{k}={_fmt(v)}" for k, v in sorted(t["overload_shed_rows"].items())
        )
        sections.append(_table("Multi-tenant (§9)", [
            ("tenants", _fmt(t["tenants"])),
            ("isolation overhead (x)", _fmt(t["isolation_overhead"])),
            ("shared sketch passes", _fmt(t["shared_sketch_passes"])),
            ("private passes avoided",
             _fmt(t["private_sketch_passes_avoided"])),
            ("tenants bit-identical", t["tenants_bit_identical"]),
            ("overload shed rows", shed),
            ("contained faults", _fmt(t["contained_faults"])),
        ]))
    if "obs" in record:
        o = record["obs"]
        series = ", ".join(
            f"{k}={_fmt(v)}" for k, v in sorted(o["metric_series"].items())
        )
        skew = o["skew"]
        triggers = "; ".join(
            f"batch {x['batch']}: {x['trigger']} "
            f"({x['observed']:.1f} > {x['threshold']:.1f})"
            for x in o["replan_triggers"]
        ) or "none"
        sections.append(_table("Observability overhead (§10)", [
            ("overhead vs plain fused (%)", _fmt(o["overhead_pct"])),
            ("obs median ingest (us)", _fmt(o["obs_median_ingest_us"])),
            ("fused median ingest (us)",
             _fmt(o["fused_median_ingest_us"])),
            ("trace events", _fmt(o["trace_events"])),
            ("span taxonomy", ", ".join(o["span_names"])),
            ("metric series", series),
            ("reducer imbalance (max/mean)", _fmt(skew["imbalance"])),
            ("HH routing hit rate", _fmt(skew["hh_hit_rate"])),
            ("replan triggers", triggers),
        ]))
    return "\n\n".join(sections) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--write-experiments", action="store_true")
    ap.add_argument(
        "--stream",
        nargs="?",
        const="BENCH_stream.json",
        default=None,
        metavar="PATH",
        help="render the streaming bench record (default BENCH_stream.json)",
    )
    args = ap.parse_args()

    if args.stream is not None:
        with open(args.stream) as fh:
            print(render_stream(json.load(fh)), end="")
        return

    md = render_markdown(build_table(args.mesh))
    if not args.write_experiments:
        print(md)
        return
    path = os.path.join(os.path.dirname(os.path.dirname(__file__)), "EXPERIMENTS.md")
    text = open(path).read()
    if _MARK in text:
        # replace marker (and any previously spliced table right after it)
        pattern = re.escape(_MARK) + r"(\n\|.*?(?:\n\|.*?)*)?"
        text = re.sub(pattern, _MARK + "\n" + md, text, count=1)
        open(path, "w").write(text)
        print(f"wrote roofline table ({args.mesh}) into EXPERIMENTS.md")
    else:
        print("marker not found in EXPERIMENTS.md")


if __name__ == "__main__":
    main()
