"""Paper Figure 2: shuffled tuples vs number of reducers k.

The paper's claim: SharesSkew's shuffle volume for the HH residual grows as
2*sqrt(k r s)  (the dotted sqrt line in Fig 2), while the naive algorithm
grows linearly (r + k*s).  We sweep k by tightening the reducer capacity q
and verify the measured engine shuffle tracks the closed form.
"""
from __future__ import annotations

import numpy as np

from repro.core import plan_shares_skew, two_way, two_way_naive_cost, two_way_skew_cost
from repro.data import paper_2way
from repro.mapreduce import run_join

from .common import emit


def main() -> None:
    rng = np.random.default_rng(1)
    data = paper_2way(rng, n_r=20_000, n_s=2_000, domain=30_000)
    r_hh = int(np.sum(data["R"][:, 1] == 7))
    s_hh = int(np.sum(data["S"][:, 0] == 7))

    rel_err_max = 0.0
    for q in (400, 200, 100, 50):
        plan = plan_shares_skew(two_way(), data, q=q)
        hh_res = next(r for r in plan.residuals if r.combo.pinned)
        k = hh_res.num_reducers
        res = run_join(two_way(), data, plan, cap_factor=5.0)
        assert res.overflow == 0
        measured_hh = res.total_comm - sum(
            r.solution.int_cost for r in plan.residuals if not r.combo.pinned
        )
        theory = two_way_skew_cost(r_hh, s_hh, k)
        naive = two_way_naive_cost(r_hh, s_hh, k)
        rel = abs(measured_hh - theory) / theory
        rel_err_max = max(rel_err_max, rel)
        emit(
            f"2way_scaling_k{k}", measured_hh,
            f"theory_2sqrt_krs={theory:.0f};naive={naive:.0f};rel_err={rel:.3f}",
        )
    emit("2way_scaling_max_rel_err_vs_sqrt_law", rel_err_max * 100,
         "percent; paper Fig 2 dotted line")


if __name__ == "__main__":
    main()
