"""Beyond-paper transfer (DESIGN.md §2): SharesSkew expert dispatch.

Routes a Zipf-skewed token batch through a MoE layer twice: with the plain
capacity-factor router (extra_slots=0 — tokens to hot experts get dropped)
and with SharesSkew replica slots (hot experts = heavy hitters get replica
grid slots).  Reports drop rates and slot-load imbalance.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.moe import init_moe_block, moe_ffn

from .common import emit


def main() -> None:
    cfg = dataclasses.replace(
        get_config("qwen2-moe-a2.7b").reduced(), n_experts=16, top_k=2, d_model=64
    )
    key = jax.random.PRNGKey(0)
    blk = init_moe_block(key, cfg)
    # skew the router: bias strongly toward 2 experts (the heavy hitters)
    bias = np.zeros((cfg.d_model, cfg.n_experts), np.float32)
    bias[:, 0] = 0.35
    bias[:, 3] = 0.25
    blk["router"] = blk["router"] + jnp.asarray(bias)

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 256, cfg.d_model)), jnp.float32)

    _, _, base = moe_ffn(blk, x, cfg, capacity_factor=1.25, extra_slots=0,
                         return_stats=True)
    _, _, skew = moe_ffn(blk, x, cfg, capacity_factor=1.25, extra_slots=8,
                         return_stats=True)

    base_drop = float(base["drop_rate"])
    skew_drop = float(skew["drop_rate"])
    emit("moe_drop_rate_capacity_router_pct", 100 * base_drop,
         "Zipf-skewed routing, cf=1.25")
    emit("moe_drop_rate_sharesskew_pct", 100 * skew_drop,
         "hot experts get replica slots (paper Ex.2 rectangle)")
    # §Perf iteration at benchmark scale: cf=1.0 viable only with replicas
    _, _, tight_plain = moe_ffn(blk, x, cfg, capacity_factor=1.0, extra_slots=0,
                                return_stats=True)
    _, _, tight_skew = moe_ffn(blk, x, cfg, capacity_factor=1.0, extra_slots=8,
                               return_stats=True)
    emit("moe_drop_rate_cf1.0_capacity_pct", 100 * float(tight_plain["drop_rate"]),
         "tight capacity, no replicas")
    emit("moe_drop_rate_cf1.0_sharesskew_pct", 100 * float(tight_skew["drop_rate"]),
         "tight capacity + replica slots (EXPERIMENTS qwen3 iter 1)")
    loads_b = np.asarray(base["slot_loads"], np.float64)
    loads_s = np.asarray(skew["slot_loads"], np.float64)
    imb_b = loads_b.max() / max(loads_b.mean(), 1e-9)
    imb_s = loads_s.max() / max(loads_s.mean(), 1e-9)
    emit("moe_slot_imbalance_capacity_router", imb_b, "max/mean slot load")
    emit("moe_slot_imbalance_sharesskew", imb_s, "")
    assert skew_drop <= base_drop, "SharesSkew must not drop more tokens"


if __name__ == "__main__":
    main()
