"""Paper §9.1 / Figure 1: 2-way join R(A,B) ⋈ S(B,C), one HH at 10%.

Compares the naive skew join (Example 1: partition big side, broadcast
small side) against SharesSkew (Example 2: x*y reducer rectangle) on
communication cost, max reducer load, and measured engine wall time.
|R| = 10*|S| like the paper (scaled for CPU).
"""
from __future__ import annotations

import numpy as np

from repro.core import plan_shares_skew, two_way, two_way_skew_cost
from repro.data import paper_2way
from repro.mapreduce import naive_two_way, oracle_join, run_join

from .common import emit, time_call


def main() -> None:
    rng = np.random.default_rng(0)
    data = paper_2way(rng, n_r=20_000, n_s=2_000, domain=30_000)
    q_cap = 100.0

    plan = plan_shares_skew(two_way(), data, q=q_cap)
    res = run_join(two_way(), data, plan, cap_factor=4.0)
    count, checksum, _, _ = oracle_join(two_way(), data)
    assert (res.count, res.checksum) == (count, checksum), "engine != oracle"
    assert res.overflow == 0

    hh_res = next(r for r in plan.residuals if r.combo.pinned)
    k_hh = hh_res.num_reducers
    stats = naive_two_way(
        data["R"], data["S"], np.array([7]), k_hh=k_hh,
        k_ord=max(1, plan.total_reducers - k_hh),
    )
    theory = two_way_skew_cost(hh_res.sizes["R"], hh_res.sizes["S"], k_hh)

    t_us = time_call(lambda: run_join(two_way(), data, plan, cap_factor=4.0))
    emit("2way_sharesskew_comm_tuples", res.total_comm,
         f"naive={stats.comm_tuples};theory_hh={theory:.0f};k_hh={k_hh}")
    emit("2way_sharesskew_max_load", res.max_load,
         f"naive={stats.max_load};imbalance={res.load_imbalance:.2f}")
    emit("2way_engine_wall", t_us, f"join_count={res.count}")
    savings = 1 - res.total_comm / stats.comm_tuples
    emit("2way_comm_savings_vs_naive_pct", 100 * savings, "paper Fig 1(a)")


if __name__ == "__main__":
    main()
