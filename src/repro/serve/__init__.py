"""Serving substrate: universal prefill/decode engine + bucketed scheduler."""
from .engine import BucketServer, Completion, Request, greedy_generate, scan_prefill

__all__ = ["BucketServer", "Completion", "Request", "greedy_generate", "scan_prefill"]
