"""Batched serving: universal scan-prefill, greedy decode, bucketed waves.

Every family exposes (init_cache, decode_step); the engine builds on just
that pair, so dense KV-cache models and recurrent-state models (RWKV6,
Zamba2) serve through the same code.  Dense models additionally get the
fast parallel prefill from ``models.transformer``.

Scheduling: requests are grouped by prompt-length bucket into fixed-size
waves (static shapes; XLA-friendly).  A wave = one prefill + N decode
steps for the whole batch.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.zoo import ModelApi


def scan_prefill(model: ModelApi, params, cache, prompts: jnp.ndarray, dtype=jnp.bfloat16):
    """Feed a [B, L] prompt through decode_step one token at a time (works
    for every family). Returns (last logits, cache)."""
    b, l = prompts.shape

    def step(cache, xs):
        tok, pos = xs
        logits, cache = model.decode_step(params, cache, tok[:, None], pos, dtype=dtype)
        return cache, logits

    toks = prompts.T  # [L, B]
    poss = jnp.arange(l, dtype=jnp.int32)
    cache, logits = jax.lax.scan(step, cache, (toks, poss))
    return logits[-1], cache


def greedy_generate(
    model: ModelApi,
    params,
    prompts: np.ndarray,  # [B, L] equal-length prompts
    max_new: int,
    max_seq: int | None = None,
    dtype=jnp.bfloat16,
) -> np.ndarray:
    """Greedy decoding; returns [B, max_new] generated tokens."""
    b, l = prompts.shape
    max_seq = max_seq or (l + max_new)
    cache = model.init_cache(b, max_seq, dtype=dtype)
    prompts_j = jnp.asarray(prompts, jnp.int32)

    @jax.jit
    def run(params, cache, prompts_j):
        logits, cache = scan_prefill(model, params, cache, prompts_j, dtype)
        first = jnp.argmax(logits, -1).astype(jnp.int32)

        def step(carry, pos):
            cache, tok = carry
            logits, cache = model.decode_step(params, cache, tok[:, None], pos, dtype=dtype)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            return (cache, nxt), tok

        (_, last), toks = jax.lax.scan(
            step, (cache, first), jnp.arange(l, l + max_new - 1, dtype=jnp.int32)
        )
        return jnp.concatenate([toks.T, last[:, None]], axis=1)

    return np.asarray(run(params, cache, prompts_j))


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # [L]
    max_new: int


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray


class BucketServer:
    """Groups requests by prompt length, serves fixed-size waves."""

    def __init__(self, model: ModelApi, params, max_batch: int = 8, dtype=jnp.bfloat16):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.dtype = dtype
        self._queue: dict[int, list[Request]] = defaultdict(list)

    def submit(self, req: Request) -> None:
        self._queue[len(req.prompt)].append(req)

    def run_wave(self) -> list[Completion]:
        """Serve the fullest bucket (up to max_batch requests)."""
        if not any(self._queue.values()):
            return []
        length = max(self._queue, key=lambda k: len(self._queue[k]))
        reqs = self._queue[length][: self.max_batch]
        self._queue[length] = self._queue[length][self.max_batch :]
        prompts = np.stack([r.prompt for r in reqs])
        max_new = max(r.max_new for r in reqs)
        out = greedy_generate(
            self.model, self.params, prompts, max_new, dtype=self.dtype
        )
        return [
            Completion(uid=r.uid, tokens=out[i, : r.max_new])
            for i, r in enumerate(reqs)
        ]

    def drain(self) -> list[Completion]:
        done: list[Completion] = []
        while any(self._queue.values()):
            done.extend(self.run_wave())
        return done
