"""Test harnesses: deterministic fault injection for the execution seams."""
from .faults import (
    KINDS,
    TARGETS,
    FaultEvent,
    FaultInjector,
    FaultReport,
    FaultSpec,
    FaultySketchTap,
    InjectedFault,
    InjectedPreemption,
)

__all__ = [
    "KINDS",
    "TARGETS",
    "FaultEvent",
    "FaultInjector",
    "FaultReport",
    "FaultSpec",
    "FaultySketchTap",
    "InjectedFault",
    "InjectedPreemption",
]
