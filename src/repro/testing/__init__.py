"""Test harnesses: deterministic fault injection for the execution seams."""
from .faults import (
    FaultEvent,
    FaultInjector,
    FaultReport,
    FaultSpec,
    FaultySketchTap,
    InjectedFault,
    InjectedPreemption,
)

__all__ = [
    "FaultEvent",
    "FaultInjector",
    "FaultReport",
    "FaultSpec",
    "FaultySketchTap",
    "InjectedFault",
    "InjectedPreemption",
]
