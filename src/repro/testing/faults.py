"""Deterministic fault injection for the execution seams (DESIGN.md §8).

The robustness claims of the speculative executor and the streaming engine
are only claims until something actually fails.  This harness injects
failures *deterministically* — by (shard, attempt) or by ingest batch, not
by random chance — at the two seams where a real deployment loses work:

  * **Reduce shards** (``mapreduce.straggler.run_with_speculation``): a
    ``FaultInjector`` wraps each shard attempt.  ``drop`` kills the attempt
    before any work, ``preempt`` kills it after the work but before the
    result is reported (compute lost), ``delay`` stalls it into straggler
    territory, and ``duplicate`` races a second copy of the attempt from
    the start.  The executor must end every faulted shard in one of two
    states — a successful retry/backup, or an explicit per-shard error that
    propagates to the caller — never a silently absorbed loss.  Shard
    results combine associatively (counts/checksums add mod 2^32), so
    duplicate completions are idempotent by construction and the harness
    verifies the final (count, checksum) is fault-invariant.
  * **Sketch increments** (``FaultySketchTap`` around ``StreamHHTracker``):
    dropped or duplicated Count-Min/SpaceSaving updates degrade *planning
    quality only* — the join fingerprint must be bit-identical, because
    correctness never depends on the sketch.  The tap records every
    tampered batch so a test can assert both halves of that contract.
  * **Hosts** (``target="host"``, consumed by the streaming engine's
    recovery subsystem, DESIGN.md §5): ``host_loss`` permanently kills a
    host at an *absolute* batch index — its reducers' carried state is
    gone and must be lineage-replayed onto survivors; ``partition``
    silences a host's heartbeats for ``heal_after`` batches without
    destroying state — the detector (correctly) declares it lost, and on
    healing the stale host is fenced and rejoins as an empty spare.
    Batch indices are absolute (``len(engine.reports)``), so a schedule
    survives checkpoint/restore without re-firing pre-kill faults.
  * **Result integrity** (``corrupt_result``): flips bytes in a shard's
    sealed result envelope after the compute but before the collector
    reads it.  Requires ``checksum_results=True`` on the runner — the CRC
    check turns silent corruption into a failed attempt (retried, or an
    explicit error), never a wrong answer.

Every injected fault is recorded as a ``FaultEvent``; ``resolve()`` maps
events to shard outcomes and ``assert_all_resolved()`` fails a test if any
fault vanished without a retry-success or an explicit report.  Host events
are resolved by the engine when recovery completes (``outcome="result"``)
or exhausts (``outcome="error"`` — still explicit, still resolved).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Iterable, Sequence

import numpy as np

KINDS = (
    "drop",
    "duplicate",
    "delay",
    "preempt",
    "host_loss",
    "partition",
    "corrupt_result",
    "poison_rows",
    "tenant_overload",
)
TARGETS = ("shard", "sketch", "host", "tenant")

POISON_MODES = ("domain", "nan", "arity", "missing")


def _poison_rows(rows, mode: str):
    """One relation's rows tampered into a schema violation the engine's
    ``_validate_batch`` must reject (``missing`` is handled by the caller,
    which drops the relation from the view entirely)."""
    rows = np.asarray(rows)
    if mode == "domain":
        if rows.shape[0] == 0:
            return np.full((1, max(1, rows.shape[-1] if rows.ndim == 2 else 1)),
                           2**40, dtype=np.int64)
        out = rows.astype(np.int64, copy=True).reshape(rows.shape)
        out.flat[0] = 2**40  # outside the int32 routing domain
        return out
    if mode == "nan":
        out = rows.astype(np.float64, copy=True)
        if out.shape[0] == 0:
            out = np.full((1, max(1, out.shape[-1] if out.ndim == 2 else 1)),
                          np.nan)
        else:
            out.flat[0] = np.nan
        return out
    if mode == "arity":
        wide = rows.reshape(rows.shape[0], -1) if rows.ndim == 2 else rows
        if wide.ndim != 2 or wide.shape[0] == 0:
            wide = np.zeros((1, 1), dtype=np.int64)
        return np.concatenate(
            [wide, np.zeros((wide.shape[0], 1), dtype=wide.dtype)], axis=1
        )
    return rows  # "missing": caller deletes the key


class InjectedFault(RuntimeError):
    """An injected shard failure (worker died before doing the work)."""


class InjectedPreemption(InjectedFault):
    """An injected preemption: the attempt finished its compute but the
    worker died before reporting — the result is lost, not the input."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault.

    ``target="shard"``: fires on shard ``shard_id``'s attempt number
    ``attempt`` (1-based; speculative/duplicate submissions count).
    ``target="sketch"``: fires on the ``batch``-th tapped observe call.
    ``target="host"``: fires at the *absolute* batch index ``batch``
    (``len(engine.reports)`` at the boundary), killing (``host_loss``) or
    partitioning (``partition``, healing after ``heal_after`` batches)
    host ``host_id``.  In multi-tenant runs ``tenant`` scopes the fault to
    one query's recovery domain ("" = every tenant, the single-tenant
    default).
    ``target="tenant"``: tampers tenant ``tenant``'s *view* of the shared
    batch at absolute index ``batch`` — ``poison_rows`` injects a
    schema-violating batch (mode ``poison``: out-of-``domain`` value, NaN,
    wrong ``arity``, ``missing`` relation) that the victim's validation
    must reject and its circuit breaker must contain; ``tenant_overload``
    inflates relation ``rel`` by ``rows`` duplicate rows so fair-share
    shedding trims the offender, not its neighbors.
    """

    kind: str  # drop | duplicate | delay | preempt | host_loss | partition
    #            | corrupt_result | poison_rows | tenant_overload
    target: str = "shard"
    shard_id: int = 0
    attempt: int = 1
    batch: int = 0  # sketch faults: which observe() call to tamper;
    #                 host/tenant faults: absolute batch index to fire at
    delay_s: float = 0.05  # delay faults: how long to stall
    host_id: int = 0  # host faults: which host dies / is partitioned
    heal_after: int = 2  # partition faults: batches until the host rejoins
    tenant: str = ""  # host/tenant faults: which query is targeted
    rel: str = ""  # tenant faults: which relation to tamper ("" = first)
    poison: str = "domain"  # poison_rows mode (POISON_MODES)
    rows: int = 1024  # tenant_overload: duplicate rows injected

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.target not in TARGETS:
            raise ValueError(f"unknown fault target {self.target!r}")
        if self.target == "sketch" and self.kind not in ("drop", "duplicate"):
            raise ValueError("sketch faults support drop/duplicate only")
        if self.kind in ("host_loss", "partition") and self.target != "host":
            raise ValueError(f"{self.kind} faults require target='host'")
        if self.target == "host" and self.kind not in ("host_loss", "partition"):
            raise ValueError("host faults support host_loss/partition only")
        if self.kind == "corrupt_result" and self.target != "shard":
            raise ValueError("corrupt_result faults require target='shard'")
        if self.kind == "partition" and self.heal_after < 1:
            raise ValueError("partition heal_after must be >= 1 batch")
        if self.kind in ("poison_rows", "tenant_overload"):
            if self.target != "tenant":
                raise ValueError(f"{self.kind} faults require target='tenant'")
            if not self.tenant:
                raise ValueError(f"{self.kind} faults need a tenant name")
        if self.target == "tenant":
            if self.kind not in ("poison_rows", "tenant_overload"):
                raise ValueError(
                    "tenant faults support poison_rows/tenant_overload only"
                )
            if self.poison not in POISON_MODES:
                raise ValueError(f"unknown poison mode {self.poison!r}")
            if self.kind == "tenant_overload" and self.rows < 1:
                raise ValueError("tenant_overload rows must be >= 1")


@dataclasses.dataclass
class FaultEvent:
    """One fault actually fired, and how it ended."""

    spec: FaultSpec
    action: str  # raised | delayed | duplicated | dropped_increment |
    #              duplicated_increment | host_lost | partitioned |
    #              poisoned | overloaded
    resolved: bool = False  # retry succeeded, or failure explicitly reported
    outcome: str = ""  # "result" | "error" once resolved ("" before/never)
    tenant: str = ""  # which recovery domain the event fired in (host
    #                   faults: an unscoped spec fires once per tenant)


@dataclasses.dataclass(frozen=True)
class FaultReport:
    """Summary of one injection run (see ``FaultInjector.report``)."""

    injected: int  # events fired
    retried_ok: int  # shard faults whose shard still produced a result
    reported: int  # shard faults whose shard ended in an explicit error
    sketch_tampered: int  # sketch increments dropped/duplicated (quality-only)
    unresolved: int  # faults with neither outcome — must be 0
    recovered: int = 0  # host faults the engine recovered from (lineage
    #                     replay or degraded repair; exhaustion counts as
    #                     ``reported``)
    contained: int = 0  # tenant faults whose blast radius stayed inside the
    #                     victim query (quarantine / counted shedding)


class FaultInjector:
    """Deterministic fault schedule + thread-safe event log.

    Pass to ``run_with_speculation`` / ``run_join_speculative`` (shard
    faults) and/or wrap an engine's tracker in ``FaultySketchTap`` (sketch
    faults).  After the run, ``resolve(outcomes)`` classifies every event
    and ``assert_all_resolved()`` enforces the never-silent contract.
    """

    def __init__(self, faults: Iterable[FaultSpec]):
        self.faults = tuple(faults)
        self.events: list[FaultEvent] = []
        self._lock = threading.Lock()

    def _record(
        self, spec: FaultSpec, action: str, tenant: str = ""
    ) -> FaultEvent:
        ev = FaultEvent(spec=spec, action=action, tenant=tenant)
        with self._lock:
            self.events.append(ev)
        return ev

    # ---- shard seam --------------------------------------------------------
    def extra_initial_attempts(self, shard_id: int) -> int:
        """How many duplicate copies of shard ``shard_id`` to race from the
        start (the ``duplicate`` fault: a retried RPC that was not lost)."""
        n = 0
        for s in self.faults:
            if (
                s.target == "shard"
                and s.kind == "duplicate"
                and s.shard_id == shard_id
            ):
                self._record(s, "duplicated")
                n += 1
        return n

    def wrap(
        self, shard_id: int, attempt: int, fn: Callable[[], object]
    ) -> Callable[[], object]:
        """Apply the faults scheduled for (shard, attempt) around ``fn``."""
        specs = [
            s
            for s in self.faults
            if s.target == "shard"
            and s.shard_id == shard_id
            and s.attempt == attempt
            and s.kind in ("drop", "delay", "preempt", "corrupt_result")
        ]
        if not specs:
            return fn

        def faulted():
            for s in specs:
                if s.kind == "delay":
                    self._record(s, "delayed")
                    time.sleep(s.delay_s)
            for s in specs:
                if s.kind == "drop":
                    self._record(s, "raised")
                    raise InjectedFault(
                        f"shard {shard_id} attempt {attempt}: injected drop"
                    )
            result = fn()
            for s in specs:
                if s.kind == "preempt":
                    self._record(s, "raised")
                    raise InjectedPreemption(
                        f"shard {shard_id} attempt {attempt}: preempted "
                        "after compute, result lost"
                    )
            for s in specs:
                if s.kind == "corrupt_result":
                    result = self._corrupt(s, shard_id, attempt, result)
            return result

        return faulted

    def _corrupt(self, spec: FaultSpec, shard_id: int, attempt: int, result):
        """Flip a byte in a sealed result's payload without updating the
        CRC — in-transit corruption the collector's checksum must catch."""
        payload = getattr(result, "payload", None)
        crc = getattr(result, "crc", None)
        if not isinstance(payload, bytes) or crc is None:
            raise RuntimeError(
                f"corrupt_result on shard {shard_id} attempt {attempt} needs "
                "a sealed result envelope — run with checksum_results=True"
            )
        self._record(spec, "corrupted")
        tampered = bytes([payload[0] ^ 0xFF]) + payload[1:]
        return dataclasses.replace(result, payload=tampered)

    # ---- sketch seam -------------------------------------------------------
    def sketch_faults(self, call_index: int) -> list[FaultSpec]:
        return [
            s
            for s in self.faults
            if s.target == "sketch" and s.batch == call_index
        ]

    # ---- host seam ---------------------------------------------------------
    def fire_host_faults(self, batch: int, tenant: str = "") -> list[FaultEvent]:
        """Record and return the host faults scheduled for the *absolute*
        batch index ``batch`` — each fires exactly once even across a
        checkpoint/restore boundary, because a restored engine resumes at
        ``len(reports)`` past every already-fired index.  The engine marks
        the returned events resolved once recovery completes (or fails
        explicitly).

        ``tenant`` is the recovery domain doing the asking: a spec scoped
        to one tenant fires only in that tenant's engine, while an
        unscoped spec (``tenant=""``) fires everywhere — so a targeted
        host loss repairs one query and leaves its neighbors' reducer
        state untouched (the isolation contract of DESIGN.md §9)."""
        events = []
        with self._lock:
            fired = {
                (id(ev.spec), ev.tenant)
                for ev in self.events
                if ev.spec.target == "host"
            }
        for s in self.faults:
            if s.target != "host" or s.batch != batch:
                continue
            if s.tenant not in ("", tenant) or (id(s), tenant) in fired:
                continue
            action = "host_lost" if s.kind == "host_loss" else "partitioned"
            events.append(self._record(s, action, tenant=tenant))
        return events

    @staticmethod
    def mark_host_event(ev: FaultEvent, recovered: bool) -> None:
        """Resolve a host event: ``recovered=True`` means lineage replay or
        degraded repair restored exactness; ``False`` means recovery was
        exhausted and the engine raised — explicit either way."""
        ev.resolved = True
        ev.outcome = "result" if recovered else "error"

    # ---- tenant seam (DESIGN.md §9) ----------------------------------------
    def apply_tenant_faults(
        self, batch: int, tenant: str, view: dict
    ) -> tuple[dict, list[FaultEvent]]:
        """Return tenant ``tenant``'s (possibly tampered) view of the
        shared batch at absolute index ``batch``, plus the events fired.

        The tampering happens *per tenant view* — the shared batch object
        is never mutated, so neighbors read pristine rows (the whole point
        of tenant-targeted injection: only the victim's ingest sees the
        poison).  The ``MultiQueryEngine`` resolves the returned events via
        ``mark_tenant_event`` once it has contained the damage (quarantine
        for poison, counted shedding for overload); an unresolved tenant
        event fails ``assert_all_resolved``.
        """
        specs = [
            s
            for s in self.faults
            if s.target == "tenant" and s.batch == batch and s.tenant == tenant
        ]
        if not specs:
            return view, []
        out = {nm: np.asarray(rows) for nm, rows in view.items()}
        events = []
        for s in specs:
            nm = s.rel or sorted(out)[0]
            if nm not in out:
                raise ValueError(
                    f"tenant fault targets relation {nm!r}, not in batch"
                )
            if s.kind == "poison_rows":
                events.append(self._record(s, "poisoned", tenant=tenant))
                out[nm] = _poison_rows(out[nm], s.poison)
                if s.poison == "missing":
                    del out[nm]
            else:
                events.append(self._record(s, "overloaded", tenant=tenant))
                rows = out[nm]
                if rows.shape[0]:
                    reps = -(-s.rows // rows.shape[0])  # ceil
                    extra = np.tile(rows, (reps, 1))[: s.rows]
                    out[nm] = np.concatenate([rows, extra], axis=0)
        return out, events

    @staticmethod
    def mark_tenant_event(ev: FaultEvent, contained: bool) -> None:
        """Resolve a tenant event: ``contained=True`` means the engine
        quarantined the victim / shed the overload with exact counters and
        every neighbor stayed bit-identical; ``False`` means containment
        itself failed (the run should fail its test)."""
        ev.resolved = True
        ev.outcome = "result" if contained else "error"

    # ---- resolution --------------------------------------------------------
    def resolve(self, outcomes: Sequence) -> None:
        """Mark each shard event resolved by its shard's final
        ``ShardOutcome``: a result (retry/backup won) or an explicit
        ``error`` both count; a missing outcome does not.  Sketch events
        are quality-only and resolve by having been recorded."""
        by_id = {o.shard_id: o for o in outcomes}
        with self._lock:
            for ev in self.events:
                if ev.spec.target == "sketch":
                    ev.resolved = True
                    continue
                if ev.spec.target == "host":
                    continue  # resolved by the engine via mark_host_event
                o = by_id.get(ev.spec.shard_id)
                if o is None:
                    ev.resolved, ev.outcome = False, ""
                elif o.result is not None:
                    ev.resolved, ev.outcome = True, "result"
                elif o.error is not None:
                    ev.resolved, ev.outcome = True, "error"
                else:
                    ev.resolved, ev.outcome = False, ""

    def report(self) -> FaultReport:
        with self._lock:
            events = list(self.events)
        retried_ok = reported = sketch = unresolved = recovered = 0
        contained = 0
        for ev in events:
            if ev.spec.target == "sketch":
                sketch += 1
            elif ev.spec.target == "tenant" and ev.outcome == "result":
                contained += 1
            elif ev.spec.target == "host" and ev.outcome == "result":
                recovered += 1
            elif ev.outcome == "result":
                retried_ok += 1
            elif ev.outcome == "error":
                reported += 1
            else:
                unresolved += 1
        return FaultReport(
            injected=len(events),
            retried_ok=retried_ok,
            reported=reported,
            sketch_tampered=sketch,
            unresolved=unresolved,
            recovered=recovered,
            contained=contained,
        )

    def assert_all_resolved(self) -> None:
        """Fail loudly if any injected fault was neither survived by a
        retry/backup nor surfaced as an explicit shard error."""
        with self._lock:
            bad = [ev for ev in self.events if not ev.resolved]
        if bad:
            raise AssertionError(
                f"{len(bad)} injected fault(s) silently absorbed: "
                + "; ".join(
                    f"{ev.spec.kind}@host{ev.spec.host_id}/batch{ev.spec.batch}"
                    if ev.spec.target == "host"
                    else f"{ev.spec.kind}@tenant{ev.spec.tenant!r}"
                    f"/batch{ev.spec.batch}"
                    if ev.spec.target == "tenant"
                    else f"{ev.spec.kind}@shard{ev.spec.shard_id}"
                    f"/attempt{ev.spec.attempt}"
                    for ev in bad
                )
            )


class FaultySketchTap:
    """Transparent proxy over ``StreamHHTracker`` that drops or duplicates
    whole-batch sketch increments per the injector's schedule.  Everything
    else (snapshots, rates, checkpoint state) passes through untouched, so
    an engine keeps working — with a degraded skew picture.  Tampering is
    quality-only by design: the engine's join fingerprint must not move.

    ``first_call`` anchors the tap's call counter: a tap on a restored
    engine must pass ``len(engine.reports)`` so batch-indexed faults that
    fired before the kill do not re-fire after the restore (the counter
    resumes where the pre-kill engine's left off).

    """

    def __init__(self, tracker, injector: FaultInjector, first_call: int = 0):
        self._tracker = tracker
        self._injector = injector
        self._calls = first_call

    def __getattr__(self, name):
        return getattr(self._tracker, name)

    def _apply(self, do_observe: Callable[[], None]) -> None:
        idx = self._calls
        self._calls += 1
        specs = self._injector.sketch_faults(idx)
        if any(s.kind == "drop" for s in specs):
            for s in specs:
                if s.kind == "drop":
                    self._injector._record(s, "dropped_increment")
            return  # the whole batch's increments are lost
        do_observe()
        for s in specs:
            if s.kind == "duplicate":
                self._injector._record(s, "duplicated_increment")
                do_observe()  # double-counted increments

    def observe(self, batch) -> None:
        self._apply(lambda: self._tracker.observe(batch))

    def observe_absorbed(self, batch, deltas) -> None:
        self._apply(lambda: self._tracker.observe_absorbed(batch, deltas))
