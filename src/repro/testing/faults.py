"""Deterministic fault injection for the execution seams (DESIGN.md §8).

The robustness claims of the speculative executor and the streaming engine
are only claims until something actually fails.  This harness injects
failures *deterministically* — by (shard, attempt) or by ingest batch, not
by random chance — at the two seams where a real deployment loses work:

  * **Reduce shards** (``mapreduce.straggler.run_with_speculation``): a
    ``FaultInjector`` wraps each shard attempt.  ``drop`` kills the attempt
    before any work, ``preempt`` kills it after the work but before the
    result is reported (compute lost), ``delay`` stalls it into straggler
    territory, and ``duplicate`` races a second copy of the attempt from
    the start.  The executor must end every faulted shard in one of two
    states — a successful retry/backup, or an explicit per-shard error that
    propagates to the caller — never a silently absorbed loss.  Shard
    results combine associatively (counts/checksums add mod 2^32), so
    duplicate completions are idempotent by construction and the harness
    verifies the final (count, checksum) is fault-invariant.
  * **Sketch increments** (``FaultySketchTap`` around ``StreamHHTracker``):
    dropped or duplicated Count-Min/SpaceSaving updates degrade *planning
    quality only* — the join fingerprint must be bit-identical, because
    correctness never depends on the sketch.  The tap records every
    tampered batch so a test can assert both halves of that contract.

Every injected fault is recorded as a ``FaultEvent``; ``resolve()`` maps
events to shard outcomes and ``assert_all_resolved()`` fails a test if any
fault vanished without a retry-success or an explicit report.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Iterable, Sequence

KINDS = ("drop", "duplicate", "delay", "preempt")
TARGETS = ("shard", "sketch")


class InjectedFault(RuntimeError):
    """An injected shard failure (worker died before doing the work)."""


class InjectedPreemption(InjectedFault):
    """An injected preemption: the attempt finished its compute but the
    worker died before reporting — the result is lost, not the input."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault.

    ``target="shard"``: fires on shard ``shard_id``'s attempt number
    ``attempt`` (1-based; speculative/duplicate submissions count).
    ``target="sketch"``: fires on the ``batch``-th tapped observe call.
    """

    kind: str  # drop | duplicate | delay | preempt
    target: str = "shard"
    shard_id: int = 0
    attempt: int = 1
    batch: int = 0  # sketch faults: which observe() call to tamper
    delay_s: float = 0.05  # delay faults: how long to stall

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.target not in TARGETS:
            raise ValueError(f"unknown fault target {self.target!r}")
        if self.target == "sketch" and self.kind in ("delay", "preempt"):
            raise ValueError("sketch faults support drop/duplicate only")


@dataclasses.dataclass
class FaultEvent:
    """One fault actually fired, and how it ended."""

    spec: FaultSpec
    action: str  # raised | delayed | duplicated | dropped_increment |
    #              duplicated_increment
    resolved: bool = False  # retry succeeded, or failure explicitly reported
    outcome: str = ""  # "result" | "error" once resolved ("" before/never)


@dataclasses.dataclass(frozen=True)
class FaultReport:
    """Summary of one injection run (see ``FaultInjector.report``)."""

    injected: int  # events fired
    retried_ok: int  # shard faults whose shard still produced a result
    reported: int  # shard faults whose shard ended in an explicit error
    sketch_tampered: int  # sketch increments dropped/duplicated (quality-only)
    unresolved: int  # faults with neither outcome — must be 0


class FaultInjector:
    """Deterministic fault schedule + thread-safe event log.

    Pass to ``run_with_speculation`` / ``run_join_speculative`` (shard
    faults) and/or wrap an engine's tracker in ``FaultySketchTap`` (sketch
    faults).  After the run, ``resolve(outcomes)`` classifies every event
    and ``assert_all_resolved()`` enforces the never-silent contract.
    """

    def __init__(self, faults: Iterable[FaultSpec]):
        self.faults = tuple(faults)
        self.events: list[FaultEvent] = []
        self._lock = threading.Lock()

    def _record(self, spec: FaultSpec, action: str) -> FaultEvent:
        ev = FaultEvent(spec=spec, action=action)
        with self._lock:
            self.events.append(ev)
        return ev

    # ---- shard seam --------------------------------------------------------
    def extra_initial_attempts(self, shard_id: int) -> int:
        """How many duplicate copies of shard ``shard_id`` to race from the
        start (the ``duplicate`` fault: a retried RPC that was not lost)."""
        n = 0
        for s in self.faults:
            if (
                s.target == "shard"
                and s.kind == "duplicate"
                and s.shard_id == shard_id
            ):
                self._record(s, "duplicated")
                n += 1
        return n

    def wrap(
        self, shard_id: int, attempt: int, fn: Callable[[], object]
    ) -> Callable[[], object]:
        """Apply the faults scheduled for (shard, attempt) around ``fn``."""
        specs = [
            s
            for s in self.faults
            if s.target == "shard"
            and s.shard_id == shard_id
            and s.attempt == attempt
            and s.kind in ("drop", "delay", "preempt")
        ]
        if not specs:
            return fn

        def faulted():
            for s in specs:
                if s.kind == "delay":
                    self._record(s, "delayed")
                    time.sleep(s.delay_s)
            for s in specs:
                if s.kind == "drop":
                    self._record(s, "raised")
                    raise InjectedFault(
                        f"shard {shard_id} attempt {attempt}: injected drop"
                    )
            result = fn()
            for s in specs:
                if s.kind == "preempt":
                    self._record(s, "raised")
                    raise InjectedPreemption(
                        f"shard {shard_id} attempt {attempt}: preempted "
                        "after compute, result lost"
                    )
            return result

        return faulted

    # ---- sketch seam -------------------------------------------------------
    def sketch_faults(self, call_index: int) -> list[FaultSpec]:
        return [
            s
            for s in self.faults
            if s.target == "sketch" and s.batch == call_index
        ]

    # ---- resolution --------------------------------------------------------
    def resolve(self, outcomes: Sequence) -> None:
        """Mark each shard event resolved by its shard's final
        ``ShardOutcome``: a result (retry/backup won) or an explicit
        ``error`` both count; a missing outcome does not.  Sketch events
        are quality-only and resolve by having been recorded."""
        by_id = {o.shard_id: o for o in outcomes}
        with self._lock:
            for ev in self.events:
                if ev.spec.target == "sketch":
                    ev.resolved = True
                    continue
                o = by_id.get(ev.spec.shard_id)
                if o is None:
                    ev.resolved, ev.outcome = False, ""
                elif o.result is not None:
                    ev.resolved, ev.outcome = True, "result"
                elif o.error is not None:
                    ev.resolved, ev.outcome = True, "error"
                else:
                    ev.resolved, ev.outcome = False, ""

    def report(self) -> FaultReport:
        with self._lock:
            events = list(self.events)
        retried_ok = reported = sketch = unresolved = 0
        for ev in events:
            if ev.spec.target == "sketch":
                sketch += 1
            elif ev.outcome == "result":
                retried_ok += 1
            elif ev.outcome == "error":
                reported += 1
            else:
                unresolved += 1
        return FaultReport(
            injected=len(events),
            retried_ok=retried_ok,
            reported=reported,
            sketch_tampered=sketch,
            unresolved=unresolved,
        )

    def assert_all_resolved(self) -> None:
        """Fail loudly if any injected fault was neither survived by a
        retry/backup nor surfaced as an explicit shard error."""
        with self._lock:
            bad = [ev for ev in self.events if not ev.resolved]
        if bad:
            raise AssertionError(
                f"{len(bad)} injected fault(s) silently absorbed: "
                + "; ".join(
                    f"{ev.spec.kind}@shard{ev.spec.shard_id}"
                    f"/attempt{ev.spec.attempt}"
                    for ev in bad
                )
            )


class FaultySketchTap:
    """Transparent proxy over ``StreamHHTracker`` that drops or duplicates
    whole-batch sketch increments per the injector's schedule.  Everything
    else (snapshots, rates, checkpoint state) passes through untouched, so
    an engine keeps working — with a degraded skew picture.  Tampering is
    quality-only by design: the engine's join fingerprint must not move."""

    def __init__(self, tracker, injector: FaultInjector):
        self._tracker = tracker
        self._injector = injector
        self._calls = 0

    def __getattr__(self, name):
        return getattr(self._tracker, name)

    def _apply(self, do_observe: Callable[[], None]) -> None:
        idx = self._calls
        self._calls += 1
        specs = self._injector.sketch_faults(idx)
        if any(s.kind == "drop" for s in specs):
            for s in specs:
                if s.kind == "drop":
                    self._injector._record(s, "dropped_increment")
            return  # the whole batch's increments are lost
        do_observe()
        for s in specs:
            if s.kind == "duplicate":
                self._injector._record(s, "duplicated_increment")
                do_observe()  # double-counted increments

    def observe(self, batch) -> None:
        self._apply(lambda: self._tracker.observe(batch))

    def observe_absorbed(self, batch, deltas) -> None:
        self._apply(lambda: self._tracker.observe_absorbed(batch, deltas))
