"""Training substrate: optimizer, train step, checkpointing, elasticity."""
from .checkpoint import (
    AsyncCheckpointer,
    latest_step,
    load_checkpoint,
    load_manifest,
    restore_tree,
    save_checkpoint,
)
from .compression import compressed_psum, compressed_tree_psum, init_residuals
from .elastic import MeshPlan, PreemptionGuard, plan_mesh_shape, run_elastic_loop
from .optimizer import OptConfig, adamw_update, init_opt_state, schedule
from .train_step import init_train_state, make_train_step

__all__ = [
    "AsyncCheckpointer",
    "MeshPlan",
    "OptConfig",
    "PreemptionGuard",
    "adamw_update",
    "compressed_psum",
    "compressed_tree_psum",
    "init_opt_state",
    "init_residuals",
    "init_train_state",
    "latest_step",
    "load_checkpoint",
    "load_manifest",
    "make_train_step",
    "plan_mesh_shape",
    "restore_tree",
    "run_elastic_loop",
    "save_checkpoint",
    "schedule",
]
