"""Gradient compression for the cross-pod all-reduce (DESIGN.md §5).

int8 quantized all-reduce with error feedback (1-bit-Adam-family trick):
each participant quantizes (grad + residual) to int8 with a shared absmax
scale, all-reduces the int8 payload (8ated: 4x fewer bytes on the slow
cross-pod link than fp32, 2x fewer than bf16), dequantizes, and keeps the
quantization error as the next step's residual — so the compression bias
telescopes instead of accumulating.

``compressed_psum`` is the shard_map building block; ``CompressedState``
carries the residual pytree between steps.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

_LEVELS = 127.0


def quantize(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g)) / _LEVELS + 1e-12
    q = jnp.clip(jnp.round(g / scale), -_LEVELS, _LEVELS).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(
    grad: jnp.ndarray,
    residual: jnp.ndarray,
    axis_name: str,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback int8 psum over ``axis_name``.

    Returns (mean gradient over the axis, new residual).  Scales are
    all-reduced (max) so every participant uses the same grid; the int8
    payload is what crosses the wire.
    """
    g = grad.astype(jnp.float32) + residual
    scale = jnp.max(jnp.abs(g)) / _LEVELS + 1e-12
    scale = jax.lax.pmax(scale, axis_name)  # shared grid
    q = jnp.clip(jnp.round(g / scale), -_LEVELS, _LEVELS)
    new_residual = g - q * scale  # error feedback
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    # jax.lax.axis_size is absent on older jax; psum of 1 is equivalent
    n = jax.lax.psum(jnp.int32(1), axis_name)
    return total.astype(jnp.float32) * scale / n, new_residual


def init_residuals(grads_template: Any) -> Any:
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_template
    )


def compressed_tree_psum(grads: Any, residuals: Any, axis_name: str) -> tuple[Any, Any]:
    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    out = [compressed_psum(g, r, axis_name) for g, r in zip(flat_g, flat_r)]
    return (
        tdef.unflatten([o[0] for o in out]),
        tdef.unflatten([o[1] for o in out]),
    )
