"""AdamW with warmup+cosine schedule, global-norm clipping (pure JAX).

Params stay fp32 (the master copy); the models cast to bf16 at the compute
boundary, so mixed precision falls out naturally.  Optimizer state m/v is
fp32 and shaped like the params — its sharding (ZeRO-1 over the data axis)
is decided by the launcher, not here.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def init_opt_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(
    params: Any, grads: Any, state: dict, cfg: OptConfig
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
