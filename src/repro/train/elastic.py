"""Elastic scaling + preemption handling (DESIGN.md §5).

On a real cluster the control plane detects dead hosts and restarts the job
with a smaller/larger slice.  The pieces that belong to the framework:

  * ``plan_mesh_shape`` — given surviving chip count and the model-parallel
    degree (fixed by the weight layout), pick the largest usable (pods,
    data, model) shape and report chips left idle.
  * resharding restore — checkpoints are mesh-agnostic
    (``checkpoint.restore_tree`` device_puts onto the new mesh's shardings),
    so shrink/grow = load the same checkpoint under a new mesh.
  * ``PreemptionGuard`` — SIGTERM flips a flag; the train loop checkpoints
    and exits cleanly at the next step boundary.
"""
from __future__ import annotations

import dataclasses
import signal
from typing import Callable


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    pods: int
    data: int
    model: int
    chips_used: int
    chips_idle: int


def plan_mesh_shape(
    healthy_chips: int,
    model_parallel: int,
    chips_per_pod: int = 256,
    min_data: int = 1,
) -> MeshPlan:
    """Largest (pods, data, model) grid with the required model-parallel
    degree.  data is per-pod; pods = full healthy pods (partial pods fold
    into a single-pod remainder mesh if they still fit model_parallel)."""
    if healthy_chips < model_parallel * min_data:
        raise ValueError(
            f"{healthy_chips} chips cannot host model_parallel={model_parallel}"
        )
    pods = healthy_chips // chips_per_pod
    if pods >= 1:
        per_pod_data = chips_per_pod // model_parallel
        used = pods * per_pod_data * model_parallel
        return MeshPlan(pods, per_pod_data, model_parallel, used, healthy_chips - used)
    data = healthy_chips // model_parallel
    used = data * model_parallel
    return MeshPlan(1, data, model_parallel, used, healthy_chips - used)


class PreemptionGuard:
    """Installs a SIGTERM/SIGINT handler that requests a clean stop."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._requested = False
        self._signals = signals
        self._old: dict = {}

    def __enter__(self) -> "PreemptionGuard":
        for s in self._signals:
            self._old[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc) -> None:
        for s, h in self._old.items():
            signal.signal(s, h)

    def _handler(self, signum, frame) -> None:
        self._requested = True

    @property
    def should_stop(self) -> bool:
        return self._requested


def run_elastic_loop(
    steps: int,
    step_fn: Callable[[int], dict],
    save_fn: Callable[[int], None],
    checkpoint_every: int = 50,
    guard: PreemptionGuard | None = None,
) -> int:
    """Drive a train loop with periodic + preemption checkpoints.
    Returns the last completed step."""
    last = -1
    for step in range(steps):
        step_fn(step)
        last = step
        if guard is not None and guard.should_stop:
            save_fn(step)
            break
        if checkpoint_every and (step + 1) % checkpoint_every == 0:
            save_fn(step)
    return last
