"""Train-step factory shared by examples, launcher, and the dry-run.

``make_train_step(model, opt_cfg)`` returns a pure (params, opt_state,
batch) -> (params, opt_state, metrics) function ready for jax.jit with
donated arguments.  MoE kwargs (capacity factor / SharesSkew extra slots)
thread through to the model loss.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.zoo import ModelApi

from .optimizer import OptConfig, adamw_update, init_opt_state


def make_train_step(
    model: ModelApi,
    opt_cfg: OptConfig,
    loss_kwargs: dict | None = None,
) -> Callable:
    loss_kwargs = dict(loss_kwargs or {})

    def train_step(params: Any, opt_state: dict, batch: dict):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, batch, **loss_kwargs)
        )(params)
        params, opt_state, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def init_train_state(model: ModelApi, key) -> tuple[Any, dict]:
    params = model.init_params(key)
    return params, init_opt_state(params)
