"""Fault-tolerant checkpointing: atomic save, N-kept, resharding restore.

Layout:  <dir>/step_<N>/
             manifest.json   (step, tree structure, shapes, dtypes)
             arrays.npz      (flattened leaves keyed by path)
         <dir>/LATEST        (atomic pointer file)

Writes go to a temp dir then ``os.replace`` (atomic on POSIX), so a host
dying mid-save can never corrupt the latest checkpoint.  Restore is
mesh-agnostic: leaves come back as numpy and are ``jax.device_put`` onto
whatever sharding the (possibly different-sized) new mesh prescribes —
this is the elastic shrink/grow path.  ``AsyncCheckpointer`` overlaps the
serialization with training (one in-flight save, back-pressure on the next).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def tenant_checkpoint_dir(directory: str, tenant: str) -> str:
    """Per-tenant namespaced sub-directory under a shared checkpoint root.

    A ``MultiQueryEngine`` (DESIGN.md §9) checkpoints every tenant's
    engine independently — same atomic step/LATEST layout, one namespace
    per query — so kill → resume restores each tenant bit-identically and
    a corrupt save in one namespace can never touch a neighbor's.  Tenant
    names are restricted to filename-safe tokens so a query id can't
    escape the root (``../``) or collide with the ``step_``/``LATEST``
    entries of a non-namespaced checkpoint.
    """
    if not tenant or not all(c.isalnum() or c in "-_." for c in tenant):
        raise ValueError(
            f"tenant name {tenant!r} is not filename-safe "
            "(alphanumerics, '-', '_', '.' only)"
        )
    if tenant.startswith(("step_", ".")) or tenant == "LATEST":
        raise ValueError(f"tenant name {tenant!r} is reserved")
    return os.path.join(directory, f"tenant_{tenant}")


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    keep: int = 3,
    metadata: dict | None = None,
) -> str:
    """``metadata``: optional JSON-able dict stored in the manifest —
    consumers (e.g. the streaming engine checkpoint, DESIGN.md §8) use it
    for format versions and non-array scalars that must survive restore."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten_with_paths(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "time": time.time(),
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic
    # atomic LATEST pointer
    ptr_tmp = os.path.join(directory, ".LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(ptr_tmp, os.path.join(directory, "LATEST"))
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(directory, name)):
        return None
    return int(name.split("_")[1])


def load_checkpoint(directory: str, step: int | None = None) -> tuple[int, dict[str, np.ndarray]]:
    """Returns (step, flat path->array dict)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    return step, flat


def load_manifest(directory: str, step: int | None = None) -> dict:
    """The manifest (incl. ``metadata``) of one checkpoint step."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        return json.load(f)


def restore_tree(template: Any, flat: dict[str, np.ndarray], shardings: Any = None) -> Any:
    """Rebuild a pytree shaped like ``template`` from a flat checkpoint.

    ``shardings``: optional matching pytree of jax.sharding.Sharding — each
    leaf is device_put with its sharding (the resharding restore: the saved
    mesh's layout is irrelevant, only the logical array matters)."""
    paths = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"{key}: ckpt shape {arr.shape} != model {np.shape(leaf)}")
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(paths[1], leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree


class AsyncCheckpointer:
    """One-in-flight background saver with back-pressure."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
