"""SharesSkew on TPU: skew-aware distributed joins + LM framework in JAX."""
__version__ = "1.0.0"
