"""Heavy-hitter identification (the paper's preliminary round).

Two paths:
  * exact -- np.unique over a column (what the experiments use; the paper's
    preliminary MapReduce round computes exactly this histogram),
  * CountMinSketch -- mergeable sketch for the 1000+-node posture, where each
    host sketches its shard and sketches are summed; candidate extraction
    keeps values whose estimate crosses the threshold.

A value is a heavy hitter when its frequency would overload one reducer:
count >= threshold, with threshold defaulting to the reducer capacity q
(paper §4: q bounds the inputs per reducer).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

_P = (1 << 61) - 1  # Mersenne prime for universal hashing


@dataclasses.dataclass(frozen=True)
class HeavyHitters:
    """HH values and their per-relation counts for one attribute."""

    attr: str
    values: tuple[int, ...]
    counts: tuple[int, ...]  # max count over relations containing attr

    def __contains__(self, v: int) -> bool:
        return v in self.values


def exact_heavy_hitters(column: np.ndarray, threshold: float) -> tuple[np.ndarray, np.ndarray]:
    """Values with count >= threshold, sorted by count descending."""
    if column.size == 0:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    vals, counts = np.unique(np.asarray(column), return_counts=True)
    mask = counts >= threshold
    vals, counts = vals[mask], counts[mask]
    order = np.argsort(-counts, kind="stable")
    return vals[order].astype(np.int64), counts[order].astype(np.int64)


class CountMinSketch:
    """Mergeable count-min sketch over int64 keys (Cormode-Muthukrishnan).

    update() is vectorized; estimates are upper bounds with
    P[err > eps*N] <= delta for width=ceil(e/eps), depth=ceil(ln 1/delta).
    """

    def __init__(self, width: int = 4096, depth: int = 5, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.width = int(width)
        self.depth = int(depth)
        # universal hash params (odd a avoids degenerate maps)
        self._a = (rng.integers(1, _P, size=depth, dtype=np.int64) | 1)
        self._b = rng.integers(0, _P, size=depth, dtype=np.int64)
        self.table = np.zeros((depth, width), dtype=np.int64)
        self.total = 0

    @classmethod
    def from_error(cls, eps: float, delta: float, seed: int = 0) -> "CountMinSketch":
        """Smallest sketch with P[estimate - count > eps*N] <= delta:
        width = ceil(e/eps), depth = ceil(ln 1/delta)."""
        width = int(math.ceil(math.e / eps))
        depth = int(math.ceil(math.log(1.0 / delta)))
        return cls(width=width, depth=max(1, depth), seed=seed)

    def _buckets(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, dtype=np.int64)
        # (a*x + b) mod p mod w, via python-int math safe from overflow
        out = np.empty((self.depth, keys.size), dtype=np.int64)
        for i in range(self.depth):
            h = (keys.astype(object) * int(self._a[i]) + int(self._b[i])) % _P
            out[i] = (h % self.width).astype(np.int64)
        return out

    def update(self, keys: np.ndarray) -> None:
        b = self._buckets(keys)
        for i in range(self.depth):
            np.add.at(self.table[i], b[i], 1)
        self.total += int(np.asarray(keys).size)

    def estimate(self, keys: np.ndarray) -> np.ndarray:
        b = self._buckets(keys)
        est = np.min(
            np.stack([self.table[i][b[i]] for i in range(self.depth)]), axis=0
        )
        return est

    def merge(self, other: "CountMinSketch") -> "CountMinSketch":
        if (self.width, self.depth) != (other.width, other.depth):
            raise ValueError("sketch shapes must match to merge")
        if not (np.array_equal(self._a, other._a) and np.array_equal(self._b, other._b)):
            raise ValueError("sketch hash seeds must match to merge")
        out = CountMinSketch(self.width, self.depth)
        out._a, out._b = self._a, self._b
        out.table = self.table + other.table
        out.total = self.total + other.total
        return out

    def heavy_hitters(self, candidates: np.ndarray, threshold: float) -> tuple[np.ndarray, np.ndarray]:
        """Filter candidate values by estimated count >= threshold."""
        candidates = np.unique(np.asarray(candidates, dtype=np.int64))
        est = self.estimate(candidates)
        mask = est >= threshold
        vals, counts = candidates[mask], est[mask]
        order = np.argsort(-counts, kind="stable")
        return vals[order], counts[order]
