"""Share computation (paper §3, §5 stage 2-3).

Minimize   cost(x) = sum_j r_j * prod_{a in repl_j} x_a
subject to prod_i x_i = k,  x_i >= 1.

In log-space (y = log x) the objective is a sum of exponentials of affine
functions and the constraint is linear, i.e. a convex (geometric) program.
We solve it with projected SLSQP, seeded by the Lagrangean balance
condition; structured joins (2-way, chains, symmetric) additionally have
closed forms in ``closed_forms.py`` that tests cross-check against this
solver.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Mapping

import numpy as np
from scipy import optimize

from .cost import CostExpression
from .dominance import share_attributes
from .schema import JoinQuery


@dataclasses.dataclass(frozen=True)
class SharesSolution:
    """Continuous + integer share assignment for one (residual) join."""

    cost_expr: CostExpression
    k: float  # reducer budget given to the solver
    shares: dict[str, float]  # continuous optimum (dominated attrs -> 1.0)
    int_shares: dict[str, int]  # rounded, prod <= k
    cost: float  # continuous optimal communication cost
    int_cost: float  # cost at the integer shares

    @property
    def num_reducers(self) -> int:
        return math.prod(self.int_shares.values()) if self.int_shares else 1

    def per_relation_cost(self) -> dict[str, float]:
        return self.cost_expr.per_relation({**self.shares})

    def replication(self, rel_name: str) -> float:
        return self.cost_expr.replication_of(rel_name, self.shares)


def _solve_log_space(expr: CostExpression, k: float) -> dict[str, float]:
    """Continuous optimum of the geometric program, shares as floats >= 1."""
    attrs = expr.share_attrs
    n = len(attrs)
    if n == 0:
        return {}
    log_k = math.log(k)
    if n == 1:
        return {attrs[0]: float(k)}

    idx = {a: i for i, a in enumerate(attrs)}
    # term j: coeff r_j, mask over y
    masks = []
    log_sizes = []
    scale = max(expr.sizes) or 1.0
    for size, repl in zip(expr.sizes, expr.repl_attrs):
        if size <= 0:
            continue
        m = np.zeros(n)
        for a in repl:
            m[idx[a]] = 1.0
        masks.append(m)
        log_sizes.append(math.log(size / scale))
    if not masks:
        # all relevant sizes zero: any feasible point
        y = np.full(n, log_k / n)
        return {a: float(math.exp(v)) for a, v in zip(attrs, y)}
    M = np.stack(masks)  # [T, n]
    ls = np.array(log_sizes)  # [T]

    def f(y: np.ndarray) -> float:
        return float(np.sum(np.exp(ls + M @ y)))

    def grad(y: np.ndarray) -> np.ndarray:
        t = np.exp(ls + M @ y)
        return M.T @ t

    cons = {
        "type": "eq",
        "fun": lambda y: np.sum(y) - log_k,
        "jac": lambda y: np.ones(n),
    }
    bounds = [(0.0, log_k)] * n
    y0 = np.full(n, log_k / n)
    best = None
    for start in (y0, np.zeros(n) + 1e-3, np.linspace(0.0, 1.0, n) * log_k / max(1, n)):
        start = np.clip(start, 0, log_k)
        # re-project start onto the constraint
        start = start + (log_k - start.sum()) / n
        start = np.clip(start, 0, log_k)
        if abs(start.sum() - log_k) > 1e-9:
            # clip broke the constraint (some coords pinned); spread remainder
            free = (start > 0) & (start < log_k)
            if free.any():
                start[free] += (log_k - start.sum()) / free.sum()
        res = optimize.minimize(
            f, start, jac=grad, bounds=bounds, constraints=[cons],
            method="SLSQP", options={"maxiter": 500, "ftol": 1e-12},
        )
        if res.success and (best is None or res.fun < best.fun):
            best = res
    if best is None:  # pragma: no cover - SLSQP failure fallback
        y = y0
    else:
        y = best.x
    return {a: float(math.exp(v)) for a, v in zip(attrs, y)}


def _round_shares(expr: CostExpression, cont: Mapping[str, float], k: float) -> dict[str, int]:
    """Round continuous shares to integers with product <= k, minimizing cost.

    Enumerates floor/ceil per attribute when feasible; falls back to floors.
    """
    attrs = expr.share_attrs
    if not attrs:
        return {}
    floors = {a: max(1, int(math.floor(cont[a] + 1e-9))) for a in attrs}
    if len(attrs) <= 12:
        best: tuple[float, dict[str, int]] | None = None
        choices = [(a, sorted({floors[a], max(1, int(math.ceil(cont[a] - 1e-9)))})) for a in attrs]
        for combo in itertools.product(*(c for _, c in choices)):
            cand = dict(zip([a for a, _ in choices], combo))
            if math.prod(cand.values()) > k + 1e-9:
                continue
            c = expr.evaluate({**cand})
            if best is None or c < best[0]:
                best = (c, cand)
        if best is not None:
            return best[1]
    return floors


def solve_shares(
    query: JoinQuery,
    sizes: Mapping[str, float],
    k: float,
    fixed_to_one: frozenset[str] | set[str] = frozenset(),
) -> SharesSolution:
    """Full pipeline: pin HH attrs to 1, apply dominance, solve, round.

    ``sizes`` are the *relevant* relation sizes for the residual join at
    hand (paper stage 3).  Returns shares for every attribute of the query
    (pinned/dominated ones mapped to 1).
    """
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    share_attrs = share_attributes(query, fixed_to_one)
    expr = CostExpression.build(query, sizes, share_attrs)
    cont = _solve_log_space(expr, float(k))
    ints = _round_shares(expr, cont, float(k))
    all_attrs = query.attributes
    shares = {a: cont.get(a, 1.0) for a in all_attrs}
    int_shares = {a: ints.get(a, 1) for a in all_attrs}
    return SharesSolution(
        cost_expr=expr,
        k=float(k),
        shares=shares,
        int_shares=int_shares,
        cost=expr.evaluate(shares),
        int_cost=expr.evaluate({a: float(v) for a, v in int_shares.items()}),
    )


def reproject_solution(sol: SharesSolution, k_new: float) -> SharesSolution:
    """Re-project an incumbent share assignment onto a new reducer budget
    without re-running the solver (the plan-repair fast path, DESIGN.md §5).

    In log-space the GP constraint is sum(y) = log k, so shrinking the
    budget slides the optimum along the constraint normal: every active
    share scales by the same factor ``(k'/k)^(1/m)`` (m = #share attrs).
    For the paper's structured joins (2-way, symmetric, triangle) the
    closed forms in ``closed_forms.py`` are exact power laws in k, so this
    scaling IS the new optimum; for general residuals it is the
    minimum-movement feasible projection of the incumbent — which is what
    plan repair wants: the repaired grid stays recognizably the old grid,
    so reducer-state migration is minimized.  A share the scaling would
    push below the x >= 1 boundary is clamped there and its budget
    redistributed over the still-free shares (water-filling), so the
    projected product never exceeds k'.
    """
    if k_new < 1:
        raise ValueError(f"k must be >= 1, got {k_new}")
    expr = sol.cost_expr
    attrs = expr.share_attrs
    if not attrs or k_new >= sol.k:
        return sol if k_new == sol.k else dataclasses.replace(sol, k=float(k_new))
    cont = {a: 1.0 for a in attrs}
    free = {a: sol.shares[a] for a in attrs if sol.shares[a] > 1.0}
    while free:
        f = min(1.0, (k_new / math.prod(free.values())) ** (1.0 / len(free)))
        scaled = {a: v * f for a, v in free.items()}
        clamped = [a for a, v in scaled.items() if v < 1.0]
        if not clamped:
            cont.update(scaled)
            break
        for a in clamped:  # pinned at the boundary; contributes 1 to prod
            free.pop(a)
    ints = _round_shares(expr, cont, float(k_new))
    all_attrs = expr.query.attributes
    shares = {a: cont.get(a, 1.0) for a in all_attrs}
    int_shares = {a: ints.get(a, 1) for a in all_attrs}
    return SharesSolution(
        cost_expr=expr,
        k=float(k_new),
        shares=shares,
        int_shares=int_shares,
        cost=expr.evaluate(shares),
        int_cost=expr.evaluate({a: float(v) for a, v in int_shares.items()}),
    )


def solve_k_for_capacity(
    query: JoinQuery,
    sizes: Mapping[str, float],
    q: float,
    fixed_to_one: frozenset[str] | set[str] = frozenset(),
    k_max: int = 1 << 22,
) -> tuple[int, SharesSolution]:
    """Paper §4.2: pick the smallest k whose expected per-reducer load
    cost*(k)/k is <= q.  Expected load is monotone nonincreasing in k, so we
    binary search.  Returns (k, solution at k)."""
    if q <= 0:
        raise ValueError("q must be positive")

    def load(k: int) -> float:
        sol = solve_shares(query, sizes, k, fixed_to_one)
        return sol.cost / k

    total = sum(float(sizes[r.name]) for r in query.relations)
    if total <= q:
        return 1, solve_shares(query, sizes, 1, fixed_to_one)
    lo, hi = 1, 2
    while hi < k_max and load(hi) > q:
        lo, hi = hi, hi * 2
    hi = min(hi, k_max)
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        if load(mid) > q:
            lo = mid
        else:
            hi = mid
    return hi, solve_shares(query, sizes, hi, fixed_to_one)
