"""Closed forms for shares and communication cost (paper §1.1, §3, §7.3, §8).

Every formula here is cross-checked against the numeric geometric-program
solver in ``shares.py`` by tests/test_closed_forms.py.

Validity note: the Lagrangean closed forms ignore the x_i >= 1 bound; for
extremely lopsided relation sizes the unconstrained optimum may push a share
below 1, in which case the numeric solver (which enforces the bound) is the
ground truth.  Each function documents its assumption.
"""
from __future__ import annotations

import math
from typing import Sequence


# ---------------------------------------------------------------------------
# 2-way join R(A,B) ⋈ S(B,C)   (Examples 1-2, §5.3, §7.3)
# ---------------------------------------------------------------------------

def two_way_naive_cost(r: float, s: float, k: float) -> float:
    """Example 1: partition the larger relation into k buckets, broadcast the
    smaller to all k reducers.  cost = larger + k * smaller."""
    big, small = max(r, s), min(r, s)
    return big + k * small


def two_way_skew_shares(r: float, s: float, k: float) -> tuple[float, float]:
    """Example 2: minimize r*y + s*x  s.t. x*y = k.
    x partitions R (i.e. hashes A), y partitions S (hashes C).
    Returns (x, y)."""
    x = math.sqrt(k * r / s)
    y = math.sqrt(k * s / r)
    return x, y


def two_way_skew_cost(r: float, s: float, k: float) -> float:
    """Example 2 / §7.3: optimal HH-residual communication = 2*sqrt(k*r*s)."""
    return 2.0 * math.sqrt(k * r * s)


def two_way_lower_bound(r: float, s: float, k: float) -> float:
    """§7.3 lower bound — equals the achieved cost (SharesSkew is optimal)."""
    return 2.0 * math.sqrt(k * r * s)


# ---------------------------------------------------------------------------
# 3-relation chain R(A,B) ⋈ S(B,C) ⋈ T(C,D)   (Example 3)
# ---------------------------------------------------------------------------

def three_chain_shares(r: float, s: float, t: float, k: float) -> tuple[float, float]:
    """Example 3: shares (x, y) for (B, C); A and D are dominated."""
    x = math.sqrt(k * r / t)
    y = math.sqrt(k * t / r)
    return x, y


def three_chain_cost(r: float, s: float, t: float, k: float) -> float:
    """Example 3: cost = r*y + s + t*x = s + 2*sqrt(k*r*t)."""
    return s + 2.0 * math.sqrt(k * r * t)


# ---------------------------------------------------------------------------
# Triangle / cyclic 3-way join (§3)
# ---------------------------------------------------------------------------

def triangle_shares(r1: float, r2: float, r3: float, k: float) -> tuple[float, float, float]:
    x1 = (k * r1 * r3 / r2**2) ** (1.0 / 3.0)
    x2 = (k * r1 * r2 / r3**2) ** (1.0 / 3.0)
    x3 = (k * r2 * r3 / r1**2) ** (1.0 / 3.0)
    return x1, x2, x3


def triangle_cost(r1: float, r2: float, r3: float, k: float) -> float:
    return 3.0 * (k * r1 * r2 * r3) ** (1.0 / 3.0)


# ---------------------------------------------------------------------------
# Chain joins  R_1(A0,A1) ⋈ ... ⋈ R_n(A_{n-1},A_n)   (§8.1-8.2)
# ---------------------------------------------------------------------------

def chain_cost_equal_sizes(n: int, r: float, k: float) -> float:
    """§8.1 (even n): cost = n * r * k^{(n-2)/n}."""
    if n % 2 != 0:
        raise ValueError("closed form stated for even-length chains")
    return n * r * k ** ((n - 2) / n)


def chain_cost(sizes: Sequence[float], k: float) -> float:
    """§8.2 (even n, arbitrary sizes):

    cost = n/2 * k^{(n-2)/n} * ((r1 r3 r5 ...)^{2/n} + (r2 r4 ...)^{2/n})
    """
    n = len(sizes)
    if n % 2 != 0:
        raise ValueError("closed form stated for even-length chains")
    odd = math.prod(sizes[0::2])   # r1, r3, ... (1-indexed odd)
    even = math.prod(sizes[1::2])  # r2, r4, ...
    lam1 = k ** (1 - 2 / n) * odd ** (2 / n)
    lam2 = k ** (1 - 2 / n) * even ** (2 / n)
    return (n / 2) * (lam1 + lam2)


def chain_shares(sizes: Sequence[float], k: float) -> list[float]:
    """§8.2 shares a_1..a_{n-1} for interior attributes A_1..A_{n-1} (even n),
    via the forward recursion  tau_i = r_i k / (a_{i-1} a_i) = lambda_parity.

    Returns the list [a_1, ..., a_{n-1}].  Raises if the unconstrained
    optimum violates a_i >= 1 (caller should fall back to the solver)."""
    n = len(sizes)
    if n % 2 != 0:
        raise ValueError("closed form stated for even-length chains")
    odd = math.prod(sizes[0::2])
    even = math.prod(sizes[1::2])
    lam1 = k ** (1 - 2 / n) * odd ** (2 / n)
    lam2 = k ** (1 - 2 / n) * even ** (2 / n)
    shares = []
    prev = 1.0  # a_0 (A_0 is dominated -> share 1)
    for i, r_i in enumerate(sizes[:-1], start=1):  # a_1 .. a_{n-1}
        lam = lam1 if i % 2 == 1 else lam2
        a_i = r_i * k / (lam * prev)
        shares.append(a_i)
        prev = a_i
    if any(a < 1.0 - 1e-6 for a in shares):
        raise ValueError(f"closed-form share < 1 (sizes too lopsided): {shares}")
    # consistency: product of shares must be k, last term must balance
    prod = math.prod(shares)
    if not math.isclose(prod, k, rel_tol=1e-6):
        raise AssertionError(f"share product {prod} != k {k}")
    return shares


def subchain_budgets(
    subchain_lengths: Sequence[int],
    k: float,
    subchain_coeffs: Sequence[float] | None = None,
) -> list[float]:
    """§8.1: a chain with m-1 heavy hitters splits into m sub-chains; subchain
    i with n_i relations costs  C_i * k_i^{(n_i-2)/n_i}.  Minimize the sum
    subject to prod k_i = k.

    ``subchain_coeffs`` C_i defaults to n_i (equal unit sizes).  Subchains
    with n_i <= 2 have exponent <= 0 -- they get k_i = 1 (no benefit from
    more reducers).  Solved exactly in log-space (convex); the paper's
    balance condition  (n_i-2) k_i^{(n_i-2)/n_i} = const  is verified in
    tests.
    """
    ns = list(subchain_lengths)
    if subchain_coeffs is None:
        coeffs = [float(n) for n in ns]
    else:
        coeffs = [float(c) for c in subchain_coeffs]
    alphas = [(n - 2) / n for n in ns]
    active = [i for i, a in enumerate(alphas) if a > 0]
    out = [1.0] * len(ns)
    if not active:
        return out
    log_k = math.log(k)
    # minimize sum_i C_i e^{alpha_i y_i}  s.t. sum y_i = log k, y_i >= 0.
    # Lagrangean: C_i alpha_i e^{alpha_i y_i} = lam  ->  y_i(lam) =
    # log(lam/(C_i alpha_i)) / alpha_i ; bisect on lam to satisfy sum = log k.
    def ysum(lam: float) -> float:
        s = 0.0
        for i in active:
            y = math.log(lam / (coeffs[i] * alphas[i])) / alphas[i]
            s += max(0.0, y)
        return s

    lo = min(coeffs[i] * alphas[i] for i in active) * 1e-12
    hi = max(coeffs[i] * alphas[i] for i in active) * 1e12
    for _ in range(200):
        mid = math.sqrt(lo * hi)
        if ysum(mid) < log_k:
            lo = mid
        else:
            hi = mid
    lam = math.sqrt(lo * hi)
    for i in active:
        y = max(0.0, math.log(lam / (coeffs[i] * alphas[i])) / alphas[i])
        out[i] = math.exp(y)
    # renormalize tiny bisection error onto the largest budget
    prod = math.prod(out)
    j = max(active, key=lambda i: out[i])
    out[j] *= k / prod
    return out


# ---------------------------------------------------------------------------
# Symmetric joins (§8.3, Theorem 2)
# ---------------------------------------------------------------------------

def symmetric_cost(n: int, d: int, sizes: Sequence[float], k: float) -> float:
    """Theorem 2:  cost = n_d * k^{1-d/n} * sum_S (prod_{i in S} r_i)^{1/n_d}

    where n_d = smallest integer with n | d*n_d  (= n / gcd(n, d)) and the
    S are the gcd(n,d) cosets {R_j, R_{j+d}, R_{j+2d}, ...} (0-indexed).
    """
    if len(sizes) != n:
        raise ValueError("need one size per relation")
    g = math.gcd(n, d)
    n_d = n // g
    total = 0.0
    for j in range(g):
        prod = 1.0
        for step in range(n_d):
            prod *= sizes[(j + step * d) % n]
        total += prod ** (1.0 / n_d)
    return n_d * k ** (1.0 - d / n) * total


def symmetric_cost_equal_sizes(n: int, d: int, r: float, k: float) -> float:
    """Equal sizes: Theorem 2 collapses to  n * r * k^{1-d/n}."""
    return n * r * k ** (1.0 - d / n)


def symmetric_shares_equal_sizes(n: int, k: float) -> float:
    """Equal sizes: all n attributes take the same share k^{1/n}."""
    return k ** (1.0 / n)
