"""SharesSkew planner (paper §4 + §5 stages 1-3).

Produces a ``SharesSkewPlan``: the list of surviving residual joins, each
with relevant sizes, a reducer budget k_J chosen so the expected
per-reducer load is <= q, integer shares (the reducer grid), and a global
reducer-id block.  The plan is consumed by ``repro.mapreduce.executor``
(stage 4: tuple distribution) and by the MoE dispatch layer.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping

import numpy as np

from .dominance import share_attributes
from .residual import (
    Combination,
    ORDINARY,
    detect_heavy_hitters,
    enumerate_combinations,
    prune_by_subsumption,
    relevant_sizes,
)
from .schema import JoinQuery
from .shares import (
    SharesSolution,
    reproject_solution,
    solve_k_for_capacity,
    solve_shares,
)


@dataclasses.dataclass(frozen=True)
class ResidualPlan:
    """One residual join: its data slice, reducer grid and share solution."""

    combo: Combination
    sizes: dict[str, int]
    k_budget: int  # k chosen by the capacity rule
    solution: SharesSolution
    reducer_offset: int  # global reducer ids [offset, offset + num_reducers)

    @property
    def grid_attrs(self) -> tuple[str, ...]:
        """Attributes with integer share > 1, in query attribute order
        (the dimensions of this residual's reducer grid)."""
        return tuple(
            a
            for a in self.solution.cost_expr.query.attributes
            if self.solution.int_shares.get(a, 1) > 1
        )

    @property
    def grid_dims(self) -> tuple[int, ...]:
        return tuple(self.solution.int_shares[a] for a in self.grid_attrs)

    @property
    def num_reducers(self) -> int:
        return int(math.prod(self.grid_dims)) if self.grid_dims else 1

    def int_replication(self, rel_attrs: tuple[str, ...]) -> int:
        """How many reducers each tuple of a relation with ``rel_attrs`` is
        sent to under the integer shares (the executor's exact model)."""
        return math.prod(
            self.solution.int_shares[a]
            for a in self.grid_attrs
            if a not in rel_attrs
        )

    def describe(self) -> str:
        dims = ", ".join(f"{a}:{d}" for a, d in zip(self.grid_attrs, self.grid_dims))
        return (
            f"residual {self.combo} sizes={self.sizes} k={self.num_reducers}"
            f" grid=[{dims}] cost={self.solution.int_cost:.0f}"
        )


@dataclasses.dataclass(frozen=True)
class SharesSkewPlan:
    query: JoinQuery
    q: float  # reducer capacity
    hh_values: dict[str, np.ndarray]
    residuals: tuple[ResidualPlan, ...]

    @property
    def total_reducers(self) -> int:
        return sum(r.num_reducers for r in self.residuals)

    @property
    def predicted_cost(self) -> float:
        """Total tuples shipped mapper->reducer (integer-share model)."""
        return sum(r.solution.int_cost for r in self.residuals)

    def describe(self) -> str:
        lines = [
            f"SharesSkew plan for {self.query}  (q={self.q:g})",
            f"  heavy hitters: "
            + (
                ", ".join(f"{a}:{v.tolist()}" for a, v in self.hh_values.items())
                or "none"
            ),
        ]
        lines += ["  " + r.describe() for r in self.residuals]
        lines.append(
            f"  total reducers={self.total_reducers} predicted_cost={self.predicted_cost:.0f}"
        )
        return "\n".join(lines)


def plan_shares_skew(
    query: JoinQuery,
    data: Mapping[str, np.ndarray],
    q: float,
    hh_threshold: float | None = None,
    max_hh_per_attr: int = 8,
    k_max: int = 1 << 22,
    prune: bool = True,
) -> SharesSkewPlan:
    """Stages 1-3 of SharesSkew (§5.2): detect HHs, prune subsumed values,
    enumerate residual joins, and solve each residual's shares under the
    per-reducer capacity q."""
    threshold = float(hh_threshold if hh_threshold is not None else q)
    candidates = share_attributes(query)  # §4.1: HHs only for non-dominated
    hh = detect_heavy_hitters(query, data, threshold, candidates, max_hh_per_attr)
    if prune and hh:
        hh, _, _ = prune_by_subsumption(query, data, hh, q, k_max)

    residuals: list[ResidualPlan] = []
    offset = 0
    for combo in enumerate_combinations(hh):
        sizes = relevant_sizes(query, data, combo, hh)
        if any(s == 0 for s in sizes.values()):
            continue  # empty residual join -> contributes no output
        pinned = frozenset(combo.pinned)
        k, sol = solve_k_for_capacity(query, sizes, q, pinned, k_max)
        rp = ResidualPlan(combo, sizes, k, sol, offset)
        residuals.append(rp)
        offset += rp.num_reducers
    return SharesSkewPlan(query, q, hh, tuple(residuals))


def plan_with_hh(
    query: JoinQuery,
    data: Mapping[str, np.ndarray],
    q: float,
    hh_values: Mapping[str, np.ndarray],
    max_hh_per_attr: int = 8,
    k_max: int = 1 << 22,
    max_combos: int = 1024,
) -> SharesSkewPlan:
    """SharesSkew stages 2-3 with an externally supplied heavy-hitter set.

    The batch planner (``plan_shares_skew``) detects HHs by an exact scan of
    ``data``; the streaming engine instead tracks HH candidates across
    micro-batches with mergeable sketches (``repro.stream.sketch``) and plans
    each epoch from that live set — ``data`` here is only the current
    micro-batch, used for residual relevant sizes and share solving.
    Candidate attrs are filtered to non-dominated share attributes and capped
    at ``max_hh_per_attr`` (sketch order is assumed count-descending).

    Unlike ``plan_shares_skew``, combinations empty on ``data`` are KEPT
    (with a 1-reducer grid): the plan outlives the batch it was solved on,
    and a residual with no relevant tuples today may receive tuples from a
    later micro-batch — dropping it would silently lose join results.
    """
    candidates = share_attributes(query)
    hh: dict[str, np.ndarray] = {}
    for attr, vals in hh_values.items():
        vals = np.asarray(vals, dtype=np.int64)
        if attr in candidates and vals.size:
            hh[attr] = vals[:max_hh_per_attr]
    # the stream must never die mid-ingest on a rich HH set: trim the
    # lowest-ranked candidates (sketch order is rate-descending) until the
    # combination space fits, rather than raising like the batch planner
    while math.prod(1 + len(v) for v in hh.values()) > max_combos:
        widest = max(hh, key=lambda a: len(hh[a]))
        if len(hh[widest]) <= 1:
            hh.pop(widest)
        else:
            hh[widest] = hh[widest][:-1]

    residuals: list[ResidualPlan] = []
    offset = 0
    for combo in enumerate_combinations(hh, max_combos):
        sizes = relevant_sizes(query, data, combo, hh)
        pinned = frozenset(combo.pinned)
        k, sol = solve_k_for_capacity(query, sizes, q, pinned, k_max)
        rp = ResidualPlan(combo, sizes, k, sol, offset)
        residuals.append(rp)
        offset += rp.num_reducers
    return SharesSkewPlan(query, q, hh, tuple(residuals))


def repair_plan(plan: SharesSkewPlan, k_max: int) -> SharesSkewPlan:
    """Re-project an incumbent plan onto a smaller reducer budget — the
    degraded-mode half of reducer-loss recovery (DESIGN.md §5).

    A replan-from-scratch (``plan_with_hh``) after host loss would re-detect
    HHs and re-enumerate combinations, moving HH values between residuals —
    and every moved combination drags its carried reducer state across the
    cluster.  Repair instead keeps the HH set and the combination list
    *identical* (zero HH-combination movement) and only shrinks each
    residual's grid: budgets scale proportionally (``k_i' = k_i * k_max /
    K``, floors summing <= k_max), and each residual's shares are
    re-projected onto its new budget via the closed-form scaling fast path
    (``reproject_solution`` — exact for the paper's structured joins, the
    minimum-movement feasible projection otherwise; no SLSQP on the
    recovery path).  Reducer-id blocks are re-packed contiguously.

    Raises ``ValueError`` when ``k_max`` cannot host one reducer per
    residual — the caller (the engine) surfaces that as recovery
    exhaustion, an explicit error rather than a silently dropped residual.
    """
    n_res = len(plan.residuals)
    if k_max < n_res:
        raise ValueError(
            f"cannot repair plan: budget {k_max} < {n_res} residuals "
            "(every combination needs at least one reducer)"
        )
    k_old = plan.total_reducers
    if k_max >= k_old:
        return plan
    budgets = [
        max(1, (r.num_reducers * k_max) // k_old) for r in plan.residuals
    ]
    # the max(1, .) floors can overshoot k_max when many residuals round up
    # from zero; shave the largest budgets until the total fits
    while sum(budgets) > k_max:
        i = max(range(n_res), key=budgets.__getitem__)
        if budgets[i] <= 1:  # pragma: no cover - guarded by k_max >= n_res
            raise ValueError("cannot repair plan: budget exhausted")
        budgets[i] -= 1
    residuals: list[ResidualPlan] = []
    offset = 0
    for r, k_i in zip(plan.residuals, budgets):
        sol = reproject_solution(r.solution, float(k_i))
        if sol.num_reducers > k_i:  # pragma: no cover - rounding guarantees <=
            sol = solve_shares(
                plan.query, r.sizes, k_i, frozenset(r.combo.pinned)
            )
        rp = ResidualPlan(r.combo, r.sizes, k_i, sol, offset)
        residuals.append(rp)
        offset += rp.num_reducers
    return SharesSkewPlan(plan.query, plan.q, plan.hh_values, tuple(residuals))


def plan_plain_shares(
    query: JoinQuery,
    data: Mapping[str, np.ndarray],
    k: int | None = None,
    q: float | None = None,
) -> SharesSkewPlan:
    """Baseline: the original Shares algorithm — a single residual join, no
    heavy-hitter handling (skew lands wherever the hash sends it).
    Give either a fixed reducer budget ``k`` or a capacity ``q``."""
    sizes = {r.name: int(np.asarray(data[r.name]).shape[0]) for r in query.relations}
    if (k is None) == (q is None):
        raise ValueError("pass exactly one of k / q")
    if k is not None:
        sol = solve_shares(query, sizes, k)
        k_budget = int(k)
        cap = sol.cost / max(1, k)
    else:
        k_budget, sol = solve_k_for_capacity(query, sizes, q)
        cap = float(q)
    combo = Combination.of({})
    rp = ResidualPlan(combo, sizes, k_budget, sol, 0)
    return SharesSkewPlan(query, cap, {}, (rp,))
