"""Residual-join enumeration and subsumption (paper §4.1, §5.1).

For each attribute X, the set of *types* L_X is {T_-} ∪ {T_b : b heavy
hitter of X}.  A *combination* C_T picks one type per attribute and defines
a residual join: the original join applied to the tuples that satisfy C_T's
constraints (ordinary type excludes all HH values of that attribute;
pinned type T_b keeps only X = b).

Subsumption (§5.1): a combination pinning B = b is unnecessary when, under
the subsuming combination's share x_B, the HH's tuples fit inside an
average hash bucket anyway — for every relation R containing B:

    x_B < relevant_size_R / count_R(b)        (paper's condition)

i.e. hashing on B spreads b's tuples no worse than ordinary values.  We
apply this as a fixed-point *demotion* loop on HH values (a demoted value
becomes ordinary everywhere), which is exactly the pairwise rule for
single-pinned combinations and a sound approximation for multi-pinned ones
(a value harmless under the all-ordinary shares is harmless under any
residual whose shares for B can only shrink relative sizes).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Mapping

import numpy as np

from .heavy_hitters import exact_heavy_hitters
from .schema import JoinQuery
from .shares import SharesSolution, solve_k_for_capacity

ORDINARY = None  # type marker for T_-


@dataclasses.dataclass(frozen=True)
class Combination:
    """A combination of types: attr -> pinned HH value, or ORDINARY.

    Only attributes that have heavy hitters appear; everything else is
    implicitly ordinary.
    """

    types: tuple[tuple[str, int | None], ...]  # sorted by attr

    @classmethod
    def of(cls, mapping: Mapping[str, int | None]) -> "Combination":
        return cls(tuple(sorted(mapping.items())))

    def as_dict(self) -> dict[str, int | None]:
        return dict(self.types)

    @property
    def pinned(self) -> dict[str, int]:
        return {a: v for a, v in self.types if v is not ORDINARY}

    def __str__(self) -> str:
        parts = [f"{a}={'_' if v is ORDINARY else v}" for a, v in self.types]
        return "{" + ", ".join(parts) + "}"


def relevant_mask(
    rel_array: np.ndarray,
    rel_attrs: tuple[str, ...],
    combo: Combination,
    hh_values: Mapping[str, np.ndarray],
) -> np.ndarray:
    """Boolean mask of tuples of one relation relevant to ``combo``."""
    mask = np.ones(rel_array.shape[0], dtype=bool)
    cd = combo.as_dict()
    for j, attr in enumerate(rel_attrs):
        if attr not in cd:
            continue
        col = rel_array[:, j]
        if cd[attr] is ORDINARY:
            hh = hh_values.get(attr)
            if hh is not None and len(hh):
                mask &= ~np.isin(col, hh)
        else:
            mask &= col == cd[attr]
    return mask


def relevant_sizes(
    query: JoinQuery,
    data: Mapping[str, np.ndarray],
    combo: Combination,
    hh_values: Mapping[str, np.ndarray],
) -> dict[str, int]:
    return {
        r.name: int(
            relevant_mask(np.asarray(data[r.name]), r.attrs, combo, hh_values).sum()
        )
        for r in query.relations
    }


def detect_heavy_hitters(
    query: JoinQuery,
    data: Mapping[str, np.ndarray],
    threshold: float,
    candidate_attrs: tuple[str, ...],
    max_hh_per_attr: int = 8,
) -> dict[str, np.ndarray]:
    """Per candidate attribute, values whose count in ANY relation containing
    the attribute reaches ``threshold`` (the paper's preliminary round)."""
    out: dict[str, np.ndarray] = {}
    for attr in candidate_attrs:
        found: dict[int, int] = {}
        for rel in query.relations_of(attr):
            col = np.asarray(data[rel.name])[:, rel.index_of(attr)]
            vals, counts = exact_heavy_hitters(col, threshold)
            for v, c in zip(vals.tolist(), counts.tolist()):
                found[v] = max(found.get(v, 0), c)
        if found:
            top = sorted(found.items(), key=lambda kv: -kv[1])[:max_hh_per_attr]
            out[attr] = np.array([v for v, _ in top], dtype=np.int64)
    return out


def max_count_in_relations(
    query: JoinQuery, data: Mapping[str, np.ndarray], attr: str, value: int
) -> dict[str, int]:
    """count_R(value) for every relation R containing attr."""
    out = {}
    for rel in query.relations_of(attr):
        col = np.asarray(data[rel.name])[:, rel.index_of(attr)]
        out[rel.name] = int((col == value).sum())
    return out


def prune_by_subsumption(
    query: JoinQuery,
    data: Mapping[str, np.ndarray],
    hh_values: dict[str, np.ndarray],
    q: float,
    k_max: int = 1 << 22,
) -> tuple[dict[str, np.ndarray], SharesSolution, int]:
    """Fixed-point demotion of subsumed HH values (see module docstring).

    Returns (surviving hh_values, all-ordinary solution, its k).
    """
    hh = {a: np.asarray(v, dtype=np.int64) for a, v in hh_values.items() if len(v)}
    while True:
        ordinary = Combination.of({a: ORDINARY for a in hh})
        sizes = relevant_sizes(query, data, ordinary, hh)
        k0, sol0 = solve_k_for_capacity(query, sizes, q, frozenset(), k_max)
        demoted = False
        for attr in list(hh):
            x_b = sol0.shares.get(attr, 1.0)
            keep = []
            for v in hh[attr].tolist():
                counts = max_count_in_relations(query, data, attr, int(v))
                # paper §5.1: subsumed when x_B < r_R / count_R(b) for all R
                harmless = all(
                    x_b < (sizes[rn] / c if c else float("inf")) or c == 0
                    for rn, c in counts.items()
                )
                if harmless:
                    demoted = True
                else:
                    keep.append(v)
            if keep:
                hh[attr] = np.array(keep, dtype=np.int64)
            else:
                del hh[attr]
                demoted = demoted or True
        if not demoted:
            return hh, sol0, k0
        if not hh:
            ordinary = Combination.of({})
            sizes = relevant_sizes(query, data, ordinary, hh)
            k0, sol0 = solve_k_for_capacity(query, sizes, q, frozenset(), k_max)
            return hh, sol0, k0


def enumerate_combinations(
    hh_values: Mapping[str, np.ndarray], max_combos: int = 1024
) -> list[Combination]:
    """Cartesian product of L_X over HH attributes (§5.1)."""
    attrs = sorted(hh_values)
    options = [[ORDINARY] + list(np.asarray(hh_values[a]).tolist()) for a in attrs]
    n = 1
    for o in options:
        n *= len(o)
    if n > max_combos:
        raise ValueError(
            f"{n} residual joins exceeds max_combos={max_combos}; "
            "raise the HH threshold or cap HHs per attribute"
        )
    return [
        Combination.of(dict(zip(attrs, choice)))
        for choice in itertools.product(*options)
    ]
