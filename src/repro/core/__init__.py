"""SharesSkew core: join schemas, share optimization, residual joins.

The paper's contribution (Afrati, Stasinopoulos, Ullman, Vassilakopoulos,
"SharesSkew: An Algorithm to Handle Skew for Joins in MapReduce", 2015)
as a composable library: ``plan_shares_skew`` produces the full plan that
``repro.mapreduce`` executes on a JAX device mesh.
"""
from .closed_forms import (
    chain_cost,
    chain_cost_equal_sizes,
    chain_shares,
    subchain_budgets,
    symmetric_cost,
    symmetric_cost_equal_sizes,
    symmetric_shares_equal_sizes,
    three_chain_cost,
    three_chain_shares,
    triangle_cost,
    triangle_shares,
    two_way_lower_bound,
    two_way_naive_cost,
    two_way_skew_cost,
    two_way_skew_shares,
)
from .cost import CostExpression
from .dominance import dominated_attributes, share_attributes
from .heavy_hitters import CountMinSketch, HeavyHitters, exact_heavy_hitters
from .planner import (
    ResidualPlan,
    SharesSkewPlan,
    plan_plain_shares,
    plan_shares_skew,
    plan_with_hh,
)
from .residual import (
    Combination,
    ORDINARY,
    detect_heavy_hitters,
    enumerate_combinations,
    prune_by_subsumption,
    relevant_mask,
    relevant_sizes,
)
from .schema import (
    JoinQuery,
    RelationSchema,
    chain_join,
    cycle_join,
    make_query,
    star_join,
    symmetric_join,
    three_way_paper,
    triangle,
    two_way,
)
from .shares import SharesSolution, solve_k_for_capacity, solve_shares

__all__ = [
    "CostExpression",
    "Combination",
    "CountMinSketch",
    "HeavyHitters",
    "JoinQuery",
    "ORDINARY",
    "RelationSchema",
    "ResidualPlan",
    "SharesSkewPlan",
    "SharesSolution",
    "chain_cost",
    "chain_cost_equal_sizes",
    "chain_join",
    "chain_shares",
    "cycle_join",
    "detect_heavy_hitters",
    "dominated_attributes",
    "enumerate_combinations",
    "exact_heavy_hitters",
    "make_query",
    "plan_plain_shares",
    "plan_shares_skew",
    "plan_with_hh",
    "prune_by_subsumption",
    "relevant_mask",
    "relevant_sizes",
    "share_attributes",
    "solve_k_for_capacity",
    "solve_shares",
    "star_join",
    "subchain_budgets",
    "symmetric_cost",
    "symmetric_cost_equal_sizes",
    "symmetric_join",
    "symmetric_shares_equal_sizes",
    "three_chain_cost",
    "three_chain_shares",
    "three_way_paper",
    "triangle",
    "triangle_cost",
    "triangle_shares",
    "two_way",
    "two_way_lower_bound",
    "two_way_naive_cost",
    "two_way_skew_cost",
    "two_way_skew_shares",
]
