"""Communication-cost expressions for the Shares family (paper §3, §5).

The generic cost of distributing relations to a grid of reducers with share
``x_i`` for attribute ``i`` is

    cost(x) = sum_j  r_j * prod_{i not in attrs(R_j)} x_i

(each tuple of R_j is replicated once per grid cell along the dimensions of
the attributes it does not contain).  Attributes with share 1 drop out.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

from .schema import JoinQuery


@dataclasses.dataclass(frozen=True)
class CostExpression:
    """cost(x) = sum_j  size_j * prod_{a in repl_attrs_j} x_a .

    ``share_attrs`` is the ordered tuple of attributes that carry a share
    variable; every other attribute has share 1 and is omitted.
    """

    query: JoinQuery
    share_attrs: tuple[str, ...]
    sizes: tuple[float, ...]  # relevant size of each relation, query order
    repl_attrs: tuple[tuple[str, ...], ...]  # per relation: share attrs it lacks

    @classmethod
    def build(
        cls,
        query: JoinQuery,
        sizes: Mapping[str, float] | Sequence[float],
        share_attrs: Sequence[str],
    ) -> "CostExpression":
        if isinstance(sizes, Mapping):
            size_tuple = tuple(float(sizes[r.name]) for r in query.relations)
        else:
            size_tuple = tuple(float(s) for s in sizes)
        if len(size_tuple) != len(query.relations):
            raise ValueError("one size per relation required")
        share_attrs = tuple(share_attrs)
        repl = tuple(
            tuple(a for a in share_attrs if a not in r.attrs)
            for r in query.relations
        )
        return cls(query, share_attrs, size_tuple, repl)

    # ---- evaluation --------------------------------------------------------
    def evaluate(self, shares: Mapping[str, float]) -> float:
        total = 0.0
        for size, attrs in zip(self.sizes, self.repl_attrs):
            total += size * math.prod(shares[a] for a in attrs)
        return total

    def per_relation(self, shares: Mapping[str, float]) -> dict[str, float]:
        """Communication contributed by each relation (tuples shipped)."""
        out = {}
        for rel, size, attrs in zip(self.query.relations, self.sizes, self.repl_attrs):
            out[rel.name] = size * math.prod(shares[a] for a in attrs)
        return out

    def replication_of(self, rel_name: str, shares: Mapping[str, float]) -> float:
        """How many reducers each tuple of ``rel_name`` is sent to."""
        i = [r.name for r in self.query.relations].index(rel_name)
        return math.prod(shares[a] for a in self.repl_attrs[i])

    def num_reducers(self, shares: Mapping[str, float]) -> float:
        return math.prod(shares[a] for a in self.share_attrs)

    def __str__(self) -> str:
        terms = []
        for rel, attrs in zip(self.query.relations, self.repl_attrs):
            prod = "".join(f"·x_{a}" for a in attrs)
            terms.append(f"{rel.name.lower()}{prod}")
        return " + ".join(terms)
