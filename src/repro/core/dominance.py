"""Dominance rule (paper §3.1).

An attribute A is *dominated* by attribute B if B appears in every relation
in which A appears (and B != A).  A dominated attribute gets share 1 in the
optimal solution, so it is removed from the cost expression before solving.

Ties (A and B appear in exactly the same relation set) are broken by
first-appearance order so exactly one of them survives.  Attributes fixed to
share 1 by the caller (e.g. heavy-hitter attributes in a residual join) are
treated as absent when computing dominance — matching the paper's stage 3,
where dominance is applied to the *residual* cost expression.
"""
from __future__ import annotations

from .schema import JoinQuery


def dominated_attributes(
    query: JoinQuery,
    fixed_to_one: frozenset[str] | set[str] = frozenset(),
) -> frozenset[str]:
    """Return the set of attributes whose share is forced to 1 by dominance.

    ``fixed_to_one`` are attributes already pinned to share 1 (heavy hitters
    in the current residual join); they cannot dominate others and are not
    re-reported.
    """
    occ = query.occurrence_sets()
    attrs = [a for a in query.attributes if a not in fixed_to_one]
    order = {a: i for i, a in enumerate(query.attributes)}
    dominated: set[str] = set()
    for a in attrs:
        for b in attrs:
            if a == b or b in dominated:
                continue
            if occ[a] <= occ[b]:
                if occ[a] == occ[b]:
                    # tie: the earlier-declared attribute survives
                    if order[b] < order[a]:
                        dominated.add(a)
                        break
                else:
                    dominated.add(a)
                    break
    return frozenset(dominated)


def share_attributes(
    query: JoinQuery,
    fixed_to_one: frozenset[str] | set[str] = frozenset(),
) -> tuple[str, ...]:
    """Attributes that receive a (possibly >1) share after pinning HH
    attributes to 1 and applying dominance."""
    dom = dominated_attributes(query, fixed_to_one)
    return tuple(
        a for a in query.attributes if a not in dom and a not in fixed_to_one
    )
