"""Join-query schema / hypergraph definitions for SharesSkew.

A multiway natural (equi-)join is a hypergraph: vertices are attributes,
hyperedges are relations. This module is pure metadata — no JAX, no data.
Relations carry *sizes* separately (they change per residual join).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class RelationSchema:
    """A named relation with an ordered attribute tuple, e.g. R(A, B)."""

    name: str
    attrs: tuple[str, ...]

    def __post_init__(self) -> None:
        if len(set(self.attrs)) != len(self.attrs):
            raise ValueError(f"duplicate attribute in {self.name}: {self.attrs}")

    def __contains__(self, attr: str) -> bool:
        return attr in self.attrs

    @property
    def arity(self) -> int:
        return len(self.attrs)

    def index_of(self, attr: str) -> int:
        return self.attrs.index(attr)

    def __str__(self) -> str:  # R(A,B)
        return f"{self.name}({','.join(self.attrs)})"


@dataclasses.dataclass(frozen=True)
class JoinQuery:
    """A multiway natural join R_1 ⋈ R_2 ⋈ ... ⋈ R_n."""

    relations: tuple[RelationSchema, ...]

    def __post_init__(self) -> None:
        names = [r.name for r in self.relations]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate relation names: {names}")

    # ---- hypergraph views -------------------------------------------------
    @property
    def attributes(self) -> tuple[str, ...]:
        """All attributes, in first-appearance order."""
        seen: dict[str, None] = {}
        for r in self.relations:
            for a in r.attrs:
                seen.setdefault(a)
        return tuple(seen)

    def relations_of(self, attr: str) -> tuple[RelationSchema, ...]:
        return tuple(r for r in self.relations if attr in r)

    def occurrence_sets(self) -> dict[str, frozenset[str]]:
        """attr -> frozenset of relation names containing it."""
        return {
            a: frozenset(r.name for r in self.relations_of(a))
            for a in self.attributes
        }

    @property
    def join_attributes(self) -> tuple[str, ...]:
        """Attributes appearing in >= 2 relations."""
        occ = self.occurrence_sets()
        return tuple(a for a in self.attributes if len(occ[a]) >= 2)

    def relation(self, name: str) -> RelationSchema:
        for r in self.relations:
            if r.name == name:
                return r
        raise KeyError(name)

    def __str__(self) -> str:
        return " ⋈ ".join(str(r) for r in self.relations)


def make_query(spec: Mapping[str, Sequence[str]] | Iterable[tuple[str, Sequence[str]]]) -> JoinQuery:
    """Build a JoinQuery from {"R": ("A","B"), "S": ("B","C")}-style specs."""
    items = spec.items() if isinstance(spec, Mapping) else spec
    return JoinQuery(tuple(RelationSchema(n, tuple(a)) for n, a in items))


# ---- canonical join families (used by closed forms, tests, benches) -------

def chain_join(n: int, attr_prefix: str = "A", rel_prefix: str = "R") -> JoinQuery:
    """R_1(A0,A1) ⋈ R_2(A1,A2) ⋈ ... ⋈ R_n(A_{n-1}, A_n).  (paper §8.1)"""
    if n < 2:
        raise ValueError("chain needs n >= 2")
    rels = [
        RelationSchema(f"{rel_prefix}{i + 1}", (f"{attr_prefix}{i}", f"{attr_prefix}{i + 1}"))
        for i in range(n)
    ]
    return JoinQuery(tuple(rels))


def cycle_join(n: int, attr_prefix: str = "A", rel_prefix: str = "R") -> JoinQuery:
    """R_1(A0,A1) ⋈ ... ⋈ R_n(A_{n-1}, A0) — symmetric join with d=2 (§8.3)."""
    if n < 3:
        raise ValueError("cycle needs n >= 3")
    rels = [
        RelationSchema(
            f"{rel_prefix}{i + 1}",
            (f"{attr_prefix}{i}", f"{attr_prefix}{(i + 1) % n}"),
        )
        for i in range(n)
    ]
    return JoinQuery(tuple(rels))


def symmetric_join(n: int, d: int, attr_prefix: str = "A", rel_prefix: str = "R") -> JoinQuery:
    """Symmetric join (paper §8.3): n relations over n attributes, relation
    R_j = (A_j, A_{j+1}, ..., A_{j+d-1}) mod n.  Every attribute appears in
    exactly d relations; every size-d window of attributes appears in exactly
    one relation."""
    if not (1 <= d < n):
        raise ValueError("need 1 <= d < n")
    rels = [
        RelationSchema(
            f"{rel_prefix}{j + 1}",
            tuple(f"{attr_prefix}{(j + i) % n}" for i in range(d)),
        )
        for j in range(n)
    ]
    return JoinQuery(tuple(rels))


def star_join(n_dims: int) -> JoinQuery:
    """Fact(F, D1..Dn) ⋈ Dim_i(D_i, X_i) star schema."""
    fact = RelationSchema("F", tuple(["K"] + [f"D{i}" for i in range(n_dims)]))
    dims = [RelationSchema(f"T{i}", (f"D{i}", f"X{i}")) for i in range(n_dims)]
    return JoinQuery((fact, *dims))


# The paper's running examples -----------------------------------------------
def two_way() -> JoinQuery:
    """R(A,B) ⋈ S(B,C) — Examples 1, 2 and §9.1."""
    return make_query({"R": ("A", "B"), "S": ("B", "C")})


def three_way_paper() -> JoinQuery:
    """R(A,B) ⋈ S(B,E,C) ⋈ T(C,D) — Examples 5-8 and §9.2."""
    return make_query({"R": ("A", "B"), "S": ("B", "E", "C"), "T": ("C", "D")})


def triangle() -> JoinQuery:
    """R1(X1,X2) ⋈ R2(X2,X3) ⋈ R3(X3,X1) — §3 example."""
    return make_query({"R1": ("X1", "X2"), "R2": ("X2", "X3"), "R3": ("X3", "X1")})
