"""Pallas TPU kernel: RWKV-6 wkv recurrence with VMEM-resident state.

TPU adaptation of the official CUDA wkv6 kernel (which keeps S in
registers/shared memory and walks time sequentially): the grid is
(batch*heads, time-chunks) with the chunk dimension sequential; the
[hd, hd] state lives in a VMEM scratch across chunks, so HBM traffic is
just the r/k/v/w inputs and y outputs (+ the state once per *sequence*,
not once per token).  This removes the state round-trip that dominates the
XLA-scan lowering's memory roofline (``benchmarks.roofline`` artifacts for
the rwkv6 family; model-level context in DESIGN.md §Arch-applicability).

jnp oracle: ``wkv6_ref`` below, re-exported through ``kernels.ref`` with
the other kernel oracles.

    y_t = r_t · (S + u ∘ (k_t ⊗ v_t));   S <- diag(w_t) S + k_t ⊗ v_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_ref, *, chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0]  # [hd]
    s = s_ref[...]  # [hd, hd] f32

    def step(t, s):
        rt = r_ref[0, t].astype(jnp.float32)  # [hd]
        kt = k_ref[0, t].astype(jnp.float32)
        vt = v_ref[0, t].astype(jnp.float32)
        wt = w_ref[0, t].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]  # [hd(i), hd(j)]
        y = ((s + u[:, None] * kv) * rt[:, None]).sum(axis=0)  # [hd]
        y_ref[0, t] = y.astype(y_ref.dtype)
        return wt[:, None] * s + kv

    s = jax.lax.fori_loop(0, chunk, step, s)
    s_ref[...] = s


def wkv6_pallas(
    r: jnp.ndarray,  # [BH, L, hd]
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,  # decay in (0, 1)
    u: jnp.ndarray,  # [BH, hd] bonus (head-broadcast done by caller)
    chunk: int = 64,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Returns y [BH, L, hd].  L must divide chunk."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bh, l, hd = r.shape
    chunk = min(chunk, l)
    if l % chunk:
        raise ValueError("L must divide chunk")
    grid = (bh, l // chunk)
    blk = pl.BlockSpec((1, chunk, hd), lambda i, j: (i, j, 0))
    return pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk),
        grid=grid,
        in_specs=[blk, blk, blk, blk, pl.BlockSpec((1, hd), lambda i, j: (i, 0))],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((bh, l, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)


def wkv6_ref(r, k, v, w, u):
    """Sequential jnp oracle, same layout as wkv6_pallas."""
    def step(s, xs):
        rt, kt, vt, wt = xs  # [BH, hd]
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bi,bij->bj", rt, s + u[..., :, None] * kv)
        return wt[..., :, None] * s + kv, y

    bh, l, hd = r.shape
    s0 = jnp.zeros((bh, hd, hd), jnp.float32)
    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w))
    _, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype)
