"""Pallas TPU kernel: histogram / bincount for heavy-hitter detection
(DESIGN.md §2; jnp oracle: ``kernels.ref.histogram_ref``).

TPU adaptation: scatter-add bincount serializes on TPU, so
we count via a block-wise one-hot comparison
``(values[:, None] == iota[None, :]).sum(0)`` — a VPU-friendly dense
reduction whose accumulator lives in VMEM across grid steps.  Negative
values are ignored (the executor uses -1 as an invalid marker).

Grid: one step per value block; the single output block is revisited every
step (index_map -> 0) and accumulated in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _histogram_kernel(vals_ref, out_ref, *, num_bins: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[...]  # [block]
    bins = jax.lax.broadcasted_iota(jnp.int32, (vals.shape[0], num_bins), 1)
    onehot = (vals[:, None] == bins) & (vals[:, None] >= 0)
    out_ref[...] += onehot.astype(jnp.int32).sum(axis=0)


def histogram_pallas(
    values: jnp.ndarray,
    num_bins: int,
    block: int = 1024,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Count occurrences of each v in [0, num_bins) over int32 ``values``."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = values.shape[0]
    block = min(block, max(n, 1))
    pad = (-n) % block
    if pad:
        values = jnp.concatenate([values, jnp.full(pad, -1, values.dtype)])
    grid = (values.shape[0] // block,)
    return pl.pallas_call(
        functools.partial(_histogram_kernel, num_bins=num_bins),
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((num_bins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_bins,), jnp.int32),
        interpret=interpret,
    )(values.astype(jnp.int32))
