"""Public jit'd wrappers for the Pallas kernels.

Each op auto-selects interpret mode on CPU (the kernels target TPU; the CPU
path executes the same kernel bodies in the Pallas interpreter, which is
what tests validate against the ``ref.py`` oracles).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .block_join import block_join_pallas, tiled_join_pallas
from .flash_attention import flash_attention_pallas
from .histogram import histogram_pallas
from .ingest_fused import fused_ingest_dense_pallas, fused_ingest_pallas
from .sketch_update import cms_update_pallas


@partial(jax.jit, static_argnames=("num_bins", "block"))
def histogram(values: jnp.ndarray, num_bins: int, block: int = 1024) -> jnp.ndarray:
    """Counts of each value in [0, num_bins); negatives ignored."""
    return histogram_pallas(values, num_bins, block=block)


@partial(jax.jit, static_argnames=("seeds", "width", "block"))
def cms_update(
    values: jnp.ndarray, seeds: tuple[int, ...], width: int, block: int = 512
) -> jnp.ndarray:
    """[depth, width] Count-Min table increment for one batch of int32 keys."""
    return cms_update_pallas(values, seeds, width, block=block)


@partial(
    jax.jit,
    static_argnames=(
        "routes", "sketch_cols", "seeds", "width", "num_reducers",
        "block", "double_buffer",
    ),
)
def fused_ingest(
    rows: jnp.ndarray,
    *,
    routes: tuple = (),
    sketch_cols: tuple[int, ...] = (),
    seeds: tuple[int, ...] = (),
    width: int = 2048,
    num_reducers: int = 1,
    block: int = 256,
    double_buffer: bool = True,
):
    """Fused streaming-ingest pass (DESIGN.md §7): one traversal computing
    map-phase destinations, the Count-Min increment, and the pack plan
    (per-reducer counts + in-destination ranks)."""
    return fused_ingest_pallas(
        rows,
        routes=routes,
        sketch_cols=sketch_cols,
        seeds=seeds,
        width=width,
        num_reducers=num_reducers,
        block=block,
        double_buffer=double_buffer,
    )


@partial(
    jax.jit,
    static_argnames=(
        "sketch_cols", "seeds", "width", "k_pad", "block", "double_buffer",
    ),
)
def fused_ingest_dense(
    rows: jnp.ndarray,
    enc: dict,
    *,
    sketch_cols: tuple[int, ...] = (),
    seeds: tuple[int, ...] = (),
    width: int = 2048,
    k_pad: int = 128,
    block: int = 256,
    double_buffer: bool = True,
):
    """Fused ingest with the route table as DYNAMIC operands (``enc`` from
    ``ingest_fused.dense_route_encoding``).  Only padded shapes and the
    sketch signature are static, so a drift replan that keeps the same
    (W_pad, k_pad) bucket reuses the compiled executable instead of paying
    a multi-second recompile (the BENCH_stream replan spike).  Returns
    PADDED ``(dest, rank, counts, cms)`` — slice to the real (N, W, K)
    outside this jit boundary."""
    return fused_ingest_dense_pallas(
        rows,
        enc,
        sketch_cols=sketch_cols,
        seeds=seeds,
        width=width,
        k_pad=k_pad,
        block=block,
        double_buffer=double_buffer,
    )


@jax.jit
def reducer_join(r_keys, r_weights, s_keys, s_weights):
    """Per-reducer (count, checksum) for binned 2-way joins [K, cap, C]."""
    return block_join_pallas(r_keys, r_weights, s_keys, s_weights)


@partial(jax.jit, static_argnames=("block_n", "block_m"))
def flat_join(r_keys, r_weights, s_keys, s_weights, block_n: int = 512, block_m: int = 512):
    """(count, checksum) for one flat 2-way join [N, C] x [M, C]."""
    return tiled_join_pallas(
        r_keys, r_weights, s_keys, s_weights, block_n=block_n, block_m=block_m
    )


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128, block_k: int = 128):
    """FlashAttention forward, GQA-aware. q [B,H,L,D], k/v [B,Hkv,L,D]."""
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q, block_k=block_k)
