"""Pallas TPU kernels for the perf-critical compute layers.

  * ``histogram`` — heavy-hitter detection (one-hot block counting)
  * ``cms_update`` — streaming Count-Min sketch increment (HH tracking)
  * ``fused_ingest`` — fused streaming ingest: map-keys + sketch + pack
    plan in one double-buffered pass (DESIGN.md §7); ``fused_ingest_dense``
    takes the route table as dynamic operands so drift replans reuse the
    compiled executable (no per-replan recompile)
  * ``reducer_join`` / ``flat_join`` — reduce-phase block equi-join
  * ``flash_attention`` — LM prefill attention (online softmax, GQA)

Kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling) and
validated on CPU via interpret mode against the pure-jnp oracles in
``ref.py``.
"""
from .ops import (
    cms_update,
    flash_attention,
    flat_join,
    fused_ingest,
    fused_ingest_dense,
    histogram,
    reducer_join,
)

__all__ = [
    "cms_update",
    "flash_attention",
    "flat_join",
    "fused_ingest",
    "fused_ingest_dense",
    "histogram",
    "reducer_join",
]
