"""Pallas TPU kernel: fused streaming-ingest pass (DESIGN.md §7; jnp oracle:
``kernels.ref.fused_ingest_ref``).

The streaming engine's ingest hot path runs three per-tuple stages that each
traverse the same micro-batch: map-phase destination ids
(``mapreduce.keys.map_phase``), decaying Count-Min sketch increments
(``kernels.sketch_update``), and per-destination send-buffer packing
(``stream.engine``).  This kernel fuses all three into ONE pass over the
tuple blocks:

  * **destinations** — the static route table of ``mapreduce.keys``
    (hash/pin/exclude/replicate per residual) evaluated in-kernel with the
    same mix32 family, emitting ``dest [N, W]`` global reducer ids
    (−1 = not emitted);
  * **sketch** — the [n_cols·depth, width] Count-Min increment accumulated
    in a VMEM-resident table across grid steps (the one-hot block-counting
    pattern of ``kernels.histogram``: scatter-add serializes on TPU,
    DESIGN.md §2); the host applies decay and absorbs the increment;
  * **pack plan** — per-reducer arrival ``counts [K]`` plus each emission's
    ``rank [N, W]`` within its destination (flat emission order, matching a
    stable sort by destination bit-for-bit).  ``bins[dest, base + rank]``
    is then a pure precomputed-index scatter: the send buffers pack with no
    sort, no searchsorted, and no data-dependent control flow.

Input streaming: rows are consumed block-by-block from HBM with
double-buffered ``make_async_copy`` DMA into VMEM scratch, so the next
block's DMA overlaps the current block's VPU compute (DESIGN.md §7 gives
the roofline; ``overlap_profile`` models it).  ``double_buffer=False``
falls back to the automatic grid pipeline, which performs the same
double-buffering implicitly.  Both variants run under interpret mode on
CPU, which is what CI exercises against the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# ONE definition of the hash family keeps host routing/sketching and the
# fused device pass in sync bit-for-bit
from repro.mapreduce.hashing import mix32_jnp as _mix32

# Route table entry (all-static, hashable — a jit static argument):
#   (offset, hashed, replica_offsets, pins, excludes)
#   hashed:  ((col, seed, dim, stride), ...)   attrs the tuple owns
#   replica_offsets: (int, ...)                flat grid offsets (the paper's
#                                              recursive_keys enumeration)
#   pins:    ((col, value), ...)               HH equality constraints
#   excludes:((col, (value, ...)), ...)        ordinary-type HH exclusions
RouteTable = tuple


def route_width(routes: RouteTable) -> int:
    """Total emission width W = sum of per-residual replication."""
    return sum(len(rep) for _, _, rep, _, _ in routes)


def _dest_block(rows, msk, routes: RouteTable):
    """[B, W] destination ids for one tuple block (−1 = not emitted).

    Mirrors ``mapreduce.keys.RouteSpec.destinations`` exactly, column
    layout included (residual-major, replica-minor).
    """
    n = rows.shape[0]
    blocks = []
    for offset, hashed, rep, pins, excludes in routes:
        base = jnp.full((n,), offset, jnp.int32)
        for col, seed, dim, stride in hashed:
            bucket = (_mix32(rows[:, col], seed) % jnp.uint32(dim)).astype(
                jnp.int32
            )
            base = base + bucket * jnp.int32(stride)
        ok = msk
        for col, value in pins:
            ok = ok & (rows[:, col] == value)
        for col, values in excludes:
            v = rows[:, col]
            bad = jnp.zeros((n,), bool)
            for hv in values:
                bad = bad | (v == hv)
            ok = ok & ~bad
        for r_off in rep:
            blocks.append(jnp.where(ok, base + jnp.int32(r_off), jnp.int32(-1)))
    return jnp.stack(blocks, axis=1)


def _cms_block(rows, msk, sketch_cols, seeds, width):
    """[n_cols*depth, width] Count-Min increment for one tuple block."""
    n = rows.shape[0]
    bins = jax.lax.broadcasted_iota(jnp.int32, (n, width), 1)
    out = []
    for col in sketch_cols:
        vals = rows[:, col]
        for seed in seeds:
            bucket = (_mix32(vals, seed) % jnp.uint32(width)).astype(jnp.int32)
            onehot = (bucket[:, None] == bins) & msk[:, None]
            out.append(onehot.astype(jnp.int32).sum(axis=0))
    return jnp.stack(out)


def _rank_counts_block(dest, prev_counts, k_pad):
    """(rank [B, W], counts_delta [k_pad]) for one block.

    rank = arrivals at this destination before this emission (earlier
    blocks via ``prev_counts``, earlier flat positions in this block via a
    dense order comparison — no sort, no scatter, VPU-only).
    """
    b, w = dest.shape
    kiota = jax.lax.broadcasted_iota(jnp.int32, (b, w, k_pad), 2)
    onehot = dest[:, :, None] == kiota  # invalid (−1) matches nothing
    base = jnp.where(onehot, prev_counts[None, None, :], 0).sum(axis=2)
    flat = (
        jax.lax.broadcasted_iota(jnp.int32, (b, w), 0) * w
        + jax.lax.broadcasted_iota(jnp.int32, (b, w), 1)
    )
    eq = dest[:, :, None, None] == dest[None, None, :, :]
    earlier = flat[None, None, :, :] < flat[:, :, None, None]
    rank_in_block = (eq & earlier).astype(jnp.int32).sum(axis=(2, 3))
    rank = jnp.where(dest >= 0, base + rank_in_block, -1)
    return rank, onehot.astype(jnp.int32).sum(axis=(0, 1))


def _unpack_refs(out_refs, *, with_route, with_sketch):
    refs = list(out_refs)
    dest_ref = rank_ref = counts_ref = cms_ref = None
    if with_route:
        dest_ref, rank_ref, counts_ref = refs[:3]
        refs = refs[3:]
    if with_sketch:
        (cms_ref,) = refs
    return dest_ref, rank_ref, counts_ref, cms_ref


def _fused_grid_kernel(
    rows_ref, *out_refs, routes, sketch_cols, seeds, width, k_pad
):
    """Grid-pipelined variant: one step per tuple block; counts and the
    sketch table are revisited every step and accumulate in VMEM."""
    dest_ref, rank_ref, counts_ref, cms_ref = _unpack_refs(
        out_refs, with_route=bool(routes), with_sketch=bool(sketch_cols)
    )
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        if counts_ref is not None:
            counts_ref[...] = jnp.zeros_like(counts_ref)
        if cms_ref is not None:
            cms_ref[...] = jnp.zeros_like(cms_ref)

    blk = rows_ref[...]  # [B, arity+1]; last column is the validity mask
    rows, msk = blk[:, :-1], blk[:, -1] != 0
    if cms_ref is not None:
        cms_ref[...] += _cms_block(rows, msk, sketch_cols, seeds, width)
    if dest_ref is not None:
        dest = _dest_block(rows, msk, routes)
        rank, delta = _rank_counts_block(dest, counts_ref[...], k_pad)
        dest_ref[...] = dest
        rank_ref[...] = rank
        counts_ref[...] += delta


def _fused_dma_kernel(
    rows_hbm, *out_refs, routes, sketch_cols, seeds, width, k_pad, block, nsteps
):
    """Double-buffered variant: rows stay in HBM; two VMEM slots are filled
    by async DMA so the copy of block i+1 overlaps the compute on block i
    (DESIGN.md §7)."""
    dest_ref, rank_ref, counts_ref, cms_ref = _unpack_refs(
        out_refs, with_route=bool(routes), with_sketch=bool(sketch_cols)
    )
    if counts_ref is not None:
        counts_ref[...] = jnp.zeros_like(counts_ref)
    if cms_ref is not None:
        cms_ref[...] = jnp.zeros_like(cms_ref)

    def body(scratch, sem):
        def get_dma(slot, i):
            return pltpu.make_async_copy(
                rows_hbm.at[pl.ds(i * block, block), :],
                scratch.at[slot],
                sem.at[slot],
            )

        get_dma(0, 0).start()

        def step(i, _):
            cur, nxt = i % 2, (i + 1) % 2

            @pl.when(i + 1 < nsteps)
            def _prefetch():
                get_dma(nxt, i + 1).start()

            get_dma(cur, i).wait()
            blk = scratch[cur]
            rows, msk = blk[:, :-1], blk[:, -1] != 0
            if cms_ref is not None:
                cms_ref[...] += _cms_block(rows, msk, sketch_cols, seeds, width)
            if dest_ref is not None:
                dest = _dest_block(rows, msk, routes)
                rank, delta = _rank_counts_block(dest, counts_ref[...], k_pad)
                dest_ref[pl.ds(i * block, block), :] = dest
                rank_ref[pl.ds(i * block, block), :] = rank
                counts_ref[...] += delta
            return _

        jax.lax.fori_loop(0, nsteps, step, None)

    pl.run_scoped(
        body,
        scratch=pltpu.VMEM((2, block, rows_hbm.shape[1]), jnp.int32),
        sem=pltpu.SemaphoreType.DMA((2,)),
    )


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def fused_ingest_pallas(
    rows: jnp.ndarray,  # [N, arity] int32
    routes: RouteTable = (),
    sketch_cols: tuple[int, ...] = (),
    seeds: tuple[int, ...] = (),
    width: int = 2048,
    num_reducers: int = 1,
    block: int = 256,
    interpret: bool | None = None,
    double_buffer: bool = True,
):
    """One fused pass over a micro-batch for one relation.

    Returns ``(dest [N, W], rank [N, W], counts [num_reducers],
    cms [n_cols, depth, width])``; the route outputs are None when
    ``routes`` is empty (sketch-only pass), ``cms`` is None when
    ``sketch_cols`` is empty (route-only pass).
    """
    if not routes and not sketch_cols:
        raise ValueError("fused ingest needs routes and/or sketch_cols")
    if sketch_cols and not seeds:
        raise ValueError("sketching requires the Count-Min row seeds")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n, arity = rows.shape
    w = route_width(routes)
    depth = len(seeds)
    n_cols = len(sketch_cols)

    # block size: keep the dense order-comparison window (block*W)^2 at
    # ~VMEM scale regardless of the plan's replication width
    if w:
        while block > 8 and block * w > 1024:
            block //= 2
    n_pad = max(_round_up(n, block), block)
    k_pad = max(_round_up(num_reducers, 128), 128)

    mask = jnp.ones((n,), jnp.int32)
    rows_aug = jnp.concatenate([rows.astype(jnp.int32), mask[:, None]], axis=1)
    if n_pad != n:
        rows_aug = jnp.concatenate(
            [rows_aug, jnp.zeros((n_pad - n, arity + 1), jnp.int32)]
        )
    nsteps = n_pad // block

    out_shapes, out_specs = [], []
    if routes:
        out_shapes += [
            jax.ShapeDtypeStruct((n_pad, w), jnp.int32),  # dest
            jax.ShapeDtypeStruct((n_pad, w), jnp.int32),  # rank
            jax.ShapeDtypeStruct((k_pad,), jnp.int32),  # counts
        ]
        out_specs += [
            pl.BlockSpec((block, w), lambda i: (i, 0)),
            pl.BlockSpec((block, w), lambda i: (i, 0)),
            pl.BlockSpec((k_pad,), lambda i: (0,)),
        ]
    if sketch_cols:
        out_shapes.append(jax.ShapeDtypeStruct((n_cols * depth, width), jnp.int32))
        out_specs.append(pl.BlockSpec((n_cols * depth, width), lambda i: (0, 0)))

    common = dict(
        routes=routes, sketch_cols=sketch_cols, seeds=tuple(seeds),
        width=width, k_pad=k_pad,
    )
    if double_buffer:
        outs = pl.pallas_call(
            functools.partial(
                _fused_dma_kernel, block=block, nsteps=nsteps, **common
            ),
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
            out_specs=tuple(
                pl.BlockSpec(memory_space=pltpu.VMEM) for _ in out_shapes
            ),
            out_shape=tuple(out_shapes),
            interpret=interpret,
        )(rows_aug)
    else:
        outs = pl.pallas_call(
            functools.partial(_fused_grid_kernel, **common),
            grid=(nsteps,),
            in_specs=[pl.BlockSpec((block, arity + 1), lambda i: (i, 0))],
            out_specs=tuple(out_specs),
            out_shape=tuple(out_shapes),
            interpret=interpret,
        )(rows_aug)

    outs = list(outs)
    dest = rank = counts = cms = None
    if routes:
        dest = outs[0][:n]
        rank = outs[1][:n]
        counts = outs[2][:num_reducers]
        outs = outs[3:]
    if sketch_cols:
        cms = outs[-1].reshape(n_cols, depth, width)
    return dest, rank, counts, cms


# ---- dynamic-route variant (replan-stable compile cache) -------------------
#
# ``fused_ingest_pallas`` bakes the route table into the compiled kernel as
# a static argument: correct, but every drift replan produces a new table
# and therefore a full recompile (~seconds) on the ingest critical path —
# the batch-0/5 spikes in BENCH_stream.json.  The dense variant passes the
# SAME routing recipe as data: per padded output column, a base offset plus
# padded per-attr (seed, dim, stride) hash terms, pin equalities, and
# exclude lists, all as int32 arrays.  Only the *padded shapes* are static
# — (W_pad, H, P, V) derived from the relation arity and the config's HH
# cap — so replans that stay within the same power-of-two replication
# bucket reuse the compiled executable and pay microseconds, not seconds.
# Column selection uses one-hot iota comparisons (no data-dependent gather,
# Pallas-safe) and the arithmetic is term-for-term identical to
# ``_dest_block``, so destinations stay bit-identical to ``map_phase``.

def dense_route_encoding(
    routes: RouteTable,
    arity: int,
    w_pad: int,
    max_values: int,
) -> dict:
    """Encode a static route table as dense int32 arrays (dynamic operands).

    Shapes: per padded flat column ``w < w_pad`` (real columns first, in
    ``_dest_block``'s residual-major/replica-minor order):

      * ``col_base [Wp]``   — residual offset + replica offset (0 padded)
      * ``col_valid [Wp]``  — 1 for real columns
      * ``h_col/h_seed/h_dim/h_stride [Wp, H]`` — hashed-attr terms, padded
        with (0, 0, 1, 0) so a padded slot contributes bucket 0 * stride 0
      * ``p_col/p_val/p_on [Wp, P]`` — pin equalities (``p_on=0`` ignored)
      * ``e_col [Wp, P]``, ``e_val/e_on [Wp, P, V]`` — exclude lists

    ``H = P = arity`` (a residual can hash/pin/exclude at most every
    attribute) and ``V = max_values`` must bound the per-attr exclude list
    (the planner's ``max_hh_per_attr``); violations raise rather than
    silently truncate.
    """
    import numpy as np

    w = route_width(routes)
    if w > w_pad:
        raise ValueError(f"w_pad {w_pad} < route width {w}")
    H = P = max(1, arity)
    V = max(1, max_values)
    enc = {
        "col_base": np.zeros(w_pad, np.int32),
        "col_valid": np.zeros(w_pad, np.int32),
        "h_col": np.zeros((w_pad, H), np.int32),
        "h_seed": np.zeros((w_pad, H), np.int32),
        "h_dim": np.ones((w_pad, H), np.int32),
        "h_stride": np.zeros((w_pad, H), np.int32),
        "p_col": np.zeros((w_pad, P), np.int32),
        "p_val": np.zeros((w_pad, P), np.int32),
        "p_on": np.zeros((w_pad, P), np.int32),
        "e_col": np.zeros((w_pad, P), np.int32),
        "e_val": np.zeros((w_pad, P, V), np.int32),
        "e_on": np.zeros((w_pad, P, V), np.int32),
    }
    col = 0
    for offset, hashed, rep, pins, excludes in routes:
        if len(hashed) > H or len(pins) > P or len(excludes) > P:
            raise ValueError(
                f"route terms exceed arity padding {H}: "
                f"{len(hashed)} hashed / {len(pins)} pins / "
                f"{len(excludes)} excludes"
            )
        for r_off in rep:
            enc["col_base"][col] = offset + r_off
            enc["col_valid"][col] = 1
            for j, (c, seed, dim, stride) in enumerate(hashed):
                enc["h_col"][col, j] = c
                enc["h_seed"][col, j] = np.int32(np.uint32(seed))
                enc["h_dim"][col, j] = dim
                enc["h_stride"][col, j] = stride
            for j, (c, value) in enumerate(pins):
                enc["p_col"][col, j] = c
                enc["p_val"][col, j] = value
                enc["p_on"][col, j] = 1
            for j, (c, values) in enumerate(excludes):
                if len(values) > V:
                    raise ValueError(
                        f"exclude list ({len(values)}) exceeds max_values "
                        f"padding ({V}); raise the pad_values hint"
                    )
                enc["e_col"][col, j] = c
                for v_i, hv in enumerate(values):
                    enc["e_val"][col, j, v_i] = hv
                    enc["e_on"][col, j, v_i] = 1
            col += 1
    return enc


_ENC_KEYS = (
    "col_base", "col_valid", "h_col", "h_seed", "h_dim", "h_stride",
    "p_col", "p_val", "p_on", "e_col", "e_val", "e_on",
)


def _dest_block_dense(rows, msk, enc):
    """[B, Wp] destination ids from the dense encoding (−1 = not emitted).

    Same math as ``_dest_block``, vectorized over padded columns; column
    selection is a one-hot multiply against an arity iota (no gather)."""
    b, arity = rows.shape
    wp, h = enc["h_col"].shape
    v = enc["e_val"].shape[2]

    def select(cols):  # cols [Wp, T] -> values [B, Wp, T]
        t = cols.shape[1]
        oh = (
            cols[:, :, None]
            == jax.lax.broadcasted_iota(jnp.int32, (wp, t, arity), 2)
        ).astype(jnp.int32)
        return (rows[:, None, None, :] * oh[None]).sum(-1)

    hv = select(enc["h_col"])  # [B, Wp, H]
    bucket = (
        _mix32(hv, enc["h_seed"][None])
        % enc["h_dim"][None].astype(jnp.uint32)
    ).astype(jnp.int32)
    base = enc["col_base"][None, :] + (bucket * enc["h_stride"][None]).sum(-1)

    pv = select(enc["p_col"])  # [B, Wp, P]
    pin_ok = ((pv == enc["p_val"][None]) | (enc["p_on"][None] == 0)).all(-1)

    ev = select(enc["e_col"])  # [B, Wp, P]
    bad = (
        (ev[:, :, :, None] == enc["e_val"][None])
        & (enc["e_on"][None] != 0)
    ).any((-1, -2))

    ok = msk[:, None] & (enc["col_valid"][None] != 0) & pin_ok & ~bad
    return jnp.where(ok, base, jnp.int32(-1))


def _fused_grid_kernel_dense(
    rows_ref, *refs, with_sketch, sketch_cols, seeds, width, k_pad
):
    enc = {k: r[...] for k, r in zip(_ENC_KEYS, refs[: len(_ENC_KEYS)])}
    dest_ref, rank_ref, counts_ref, cms_ref = _unpack_refs(
        refs[len(_ENC_KEYS):], with_route=True, with_sketch=with_sketch
    )
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)
        if cms_ref is not None:
            cms_ref[...] = jnp.zeros_like(cms_ref)

    blk = rows_ref[...]
    rows, msk = blk[:, :-1], blk[:, -1] != 0
    if cms_ref is not None:
        cms_ref[...] += _cms_block(rows, msk, sketch_cols, seeds, width)
    dest = _dest_block_dense(rows, msk, enc)
    rank, delta = _rank_counts_block(dest, counts_ref[...], k_pad)
    dest_ref[...] = dest
    rank_ref[...] = rank
    counts_ref[...] += delta


def _fused_dma_kernel_dense(
    rows_hbm, *refs, with_sketch, sketch_cols, seeds, width, k_pad, block,
    nsteps,
):
    enc = {k: r[...] for k, r in zip(_ENC_KEYS, refs[: len(_ENC_KEYS)])}
    dest_ref, rank_ref, counts_ref, cms_ref = _unpack_refs(
        refs[len(_ENC_KEYS):], with_route=True, with_sketch=with_sketch
    )
    counts_ref[...] = jnp.zeros_like(counts_ref)
    if cms_ref is not None:
        cms_ref[...] = jnp.zeros_like(cms_ref)

    def body(scratch, sem):
        def get_dma(slot, i):
            return pltpu.make_async_copy(
                rows_hbm.at[pl.ds(i * block, block), :],
                scratch.at[slot],
                sem.at[slot],
            )

        get_dma(0, 0).start()

        def step(i, _):
            cur, nxt = i % 2, (i + 1) % 2

            @pl.when(i + 1 < nsteps)
            def _prefetch():
                get_dma(nxt, i + 1).start()

            get_dma(cur, i).wait()
            blk = scratch[cur]
            rows, msk = blk[:, :-1], blk[:, -1] != 0
            if cms_ref is not None:
                cms_ref[...] += _cms_block(rows, msk, sketch_cols, seeds, width)
            dest = _dest_block_dense(rows, msk, enc)
            rank, delta = _rank_counts_block(dest, counts_ref[...], k_pad)
            dest_ref[pl.ds(i * block, block), :] = dest
            rank_ref[pl.ds(i * block, block), :] = rank
            counts_ref[...] += delta
            return _

        jax.lax.fori_loop(0, nsteps, step, None)

    pl.run_scoped(
        body,
        scratch=pltpu.VMEM((2, block, rows_hbm.shape[1]), jnp.int32),
        sem=pltpu.SemaphoreType.DMA((2,)),
    )


def fused_ingest_dense_pallas(
    rows: jnp.ndarray,  # [N, arity] int32
    enc: dict,  # dense_route_encoding arrays (dynamic operands)
    sketch_cols: tuple[int, ...] = (),
    seeds: tuple[int, ...] = (),
    width: int = 2048,
    k_pad: int = 128,
    block: int = 256,
    interpret: bool | None = None,
    double_buffer: bool = True,
):
    """``fused_ingest_pallas`` with the routes as data, not code.

    Returns padded ``(dest [N_pad, Wp], rank [N_pad, Wp], counts [k_pad],
    cms [n_cols, depth, width] | None)`` — the caller slices to the real
    (N, W, K), which live outside the compile cache on purpose.  The only
    static inputs are padded shapes and the sketch signature, so replans
    within the same (Wp, k_pad) bucket hit the compiled executable.

    ``k_pad`` MUST be >= the plan's total reducers: destination ids are
    dynamic, so a too-small histogram cannot be detected at trace time and
    silently corrupts counts/ranks (the engine rounds total_reducers up to
    a 128 multiple in ``_dense_routes``).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n, arity = rows.shape
    wp = enc["col_base"].shape[0]
    depth = len(seeds)
    n_cols = len(sketch_cols)

    block = int(block)
    while block > 8 and block * wp > 1024:
        block //= 2
    n_pad = max(_round_up(n, block), block)

    mask = jnp.ones((n,), jnp.int32)
    rows_aug = jnp.concatenate([rows.astype(jnp.int32), mask[:, None]], axis=1)
    if n_pad != n:
        rows_aug = jnp.concatenate(
            [rows_aug, jnp.zeros((n_pad - n, arity + 1), jnp.int32)]
        )
    nsteps = n_pad // block
    enc_arrays = [jnp.asarray(enc[k], jnp.int32) for k in _ENC_KEYS]

    out_shapes = [
        jax.ShapeDtypeStruct((n_pad, wp), jnp.int32),  # dest
        jax.ShapeDtypeStruct((n_pad, wp), jnp.int32),  # rank
        jax.ShapeDtypeStruct((k_pad,), jnp.int32),  # counts
    ]
    out_specs = [
        pl.BlockSpec((block, wp), lambda i: (i, 0)),
        pl.BlockSpec((block, wp), lambda i: (i, 0)),
        pl.BlockSpec((k_pad,), lambda i: (0,)),
    ]
    if sketch_cols:
        out_shapes.append(
            jax.ShapeDtypeStruct((n_cols * depth, width), jnp.int32)
        )
        out_specs.append(pl.BlockSpec((n_cols * depth, width), lambda i: (0, 0)))

    common = dict(
        with_sketch=bool(sketch_cols), sketch_cols=sketch_cols,
        seeds=tuple(seeds), width=width, k_pad=k_pad,
    )
    if double_buffer:
        outs = pl.pallas_call(
            functools.partial(
                _fused_dma_kernel_dense, block=block, nsteps=nsteps, **common
            ),
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)]
            + [pl.BlockSpec(memory_space=pltpu.VMEM) for _ in enc_arrays],
            out_specs=tuple(
                pl.BlockSpec(memory_space=pltpu.VMEM) for _ in out_shapes
            ),
            out_shape=tuple(out_shapes),
            interpret=interpret,
        )(rows_aug, *enc_arrays)
    else:
        outs = pl.pallas_call(
            functools.partial(_fused_grid_kernel_dense, **common),
            grid=(nsteps,),
            in_specs=[pl.BlockSpec((block, arity + 1), lambda i: (i, 0))]
            + [
                pl.BlockSpec(a.shape, _zero_index_map(a.ndim))
                for a in enc_arrays
            ],
            out_specs=tuple(out_specs),
            out_shape=tuple(out_shapes),
            interpret=interpret,
        )(rows_aug, *enc_arrays)

    outs = list(outs)
    cms = None
    if sketch_cols:
        cms = outs[-1].reshape(n_cols, depth, width)
    return outs[0], outs[1], outs[2], cms


def _zero_index_map(ndim: int):
    return lambda i, _nd=ndim: (0,) * _nd


# ---- roofline / overlap model (DESIGN.md §7) -------------------------------
# Per-chip numbers for a TPU v5e-class part; the model is about orders of
# magnitude, not decimal places.
HBM_BYTES_PER_S = 819e9  # ~819 GB/s HBM bandwidth
VPU_INT_OPS_PER_S = 3.0e12  # 8x128 VPU lanes, ~1 op/lane/cycle @ ~940MHz x ~4

def overlap_profile(
    n_rows: int,
    arity: int,
    route_w: int,
    num_reducers: int,
    n_sketch_cols: int,
    depth: int,
    width: int,
    block: int = 256,
) -> dict:
    """Model the fused pass against the hardware roofline.

    Returns modeled HBM traffic, VPU work, the serial vs double-buffered
    time, and which side of the roofline binds.  ``bench_stream`` writes
    this next to the measured wall times so the gap between "what the
    kernel does" and "what the host pays" stays visible.
    """
    if route_w:
        while block > 8 and block * route_w > 1024:
            block //= 2
    bytes_in = n_rows * (arity + 1) * 4
    bytes_out = (2 * n_rows * route_w + num_reducers + n_sketch_cols * depth * width) * 4
    dma_s = (bytes_in + bytes_out) / HBM_BYTES_PER_S

    e = block * route_w  # flat emissions per block
    nsteps = max(1, -(-n_rows // block)) if block else 1
    k_pad = max(_round_up(num_reducers, 128), 128)
    ops_rank = nsteps * (3 * e * e + 3 * e * k_pad)  # order compare + one-hot
    ops_dest = n_rows * route_w * 8  # mix32 + pin/exclude masks
    ops_cms = n_rows * n_sketch_cols * depth * (width * 2 + 8)
    vpu_ops = ops_rank + ops_dest + ops_cms
    compute_s = vpu_ops / VPU_INT_OPS_PER_S

    serial_s = dma_s + compute_s
    overlapped_s = max(dma_s, compute_s)
    return {
        "bytes_in": bytes_in,
        "bytes_out": bytes_out,
        "vpu_ops": vpu_ops,
        "dma_us": dma_s * 1e6,
        "compute_us": compute_s * 1e6,
        "serial_us": serial_s * 1e6,
        "overlapped_us": overlapped_s * 1e6,
        "overlap_speedup": serial_s / overlapped_s if overlapped_s else 1.0,
        "bound": "compute" if compute_s >= dma_s else "memory",
    }
