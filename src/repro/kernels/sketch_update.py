"""Pallas TPU kernel: incremental Count-Min sketch update (DESIGN.md §6;
jnp oracle: ``kernels.ref.cms_update_ref``).

The streaming engine tracks heavy-hitter candidates across micro-batches
with decaying Count-Min sketches (``repro.stream.sketch``).  The per-batch
table increment is a [depth, width] histogram of hashed bucket ids — the
same one-hot block-counting pattern as ``kernels.histogram`` (DESIGN.md §2:
scatter-add serializes on TPU), computed once per hash row with the mix32
universal family of ``repro.mapreduce.hashing`` so host and device buckets
agree bit-for-bit.

Grid: one step per value block; the single [depth, width] output block is
revisited every step and accumulated in VMEM.  Invalid slots (padding) are
masked out via an explicit mask input — any int32 value is a legal key, so
no in-band sentinel exists.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# plain jnp ops, legal inside a Pallas kernel body — ONE definition of the
# hash family keeps host estimates and device increments in sync
from repro.mapreduce.hashing import mix32_jnp as _mix32


def _cms_update_kernel(
    vals_ref, mask_ref, out_ref, *, seeds: tuple[int, ...], width: int
):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[...]  # [block]
    mask = mask_ref[...] != 0  # [block]
    bins = jax.lax.broadcasted_iota(jnp.int32, (vals.shape[0], width), 1)
    for row, seed in enumerate(seeds):
        bucket = (_mix32(vals, seed) % jnp.uint32(width)).astype(jnp.int32)
        onehot = (bucket[:, None] == bins) & mask[:, None]
        out_ref[row, :] += onehot.astype(jnp.int32).sum(axis=0)


def cms_update_pallas(
    values: jnp.ndarray,
    seeds: tuple[int, ...],
    width: int,
    block: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """[depth, width] int32 bucket-count increment for one batch of keys.

    ``seeds`` selects the mix32 hash row family (one seed per sketch row);
    the caller's sketch must use the same seeds for buckets to line up.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    depth = len(seeds)
    n = values.shape[0]
    if n == 0:
        return jnp.zeros((depth, width), jnp.int32)
    block = min(block, max(n, 1))
    pad = (-n) % block
    mask = jnp.ones(n, dtype=jnp.int32)
    if pad:
        values = jnp.concatenate([values, jnp.zeros(pad, values.dtype)])
        mask = jnp.concatenate([mask, jnp.zeros(pad, jnp.int32)])
    grid = (values.shape[0] // block,)
    return pl.pallas_call(
        functools.partial(_cms_update_kernel, seeds=tuple(seeds), width=width),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((depth, width), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((depth, width), jnp.int32),
        interpret=interpret,
    )(values.astype(jnp.int32), mask)
