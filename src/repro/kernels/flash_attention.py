"""Pallas TPU kernel: FlashAttention forward (causal, GQA) — the LM
compute hotspot for prefill/scoring (model context: DESIGN.md
§Arch-applicability; jnp oracle: ``kernels.ref.attention_ref``).

Online-softmax over KV blocks (Dao et al. '22 adapted to TPU): grid is
(batch*heads, q_blocks, kv_blocks) with the kv dimension innermost and
sequential; running max / denominator / accumulator live in VMEM scratch
across kv steps.  Block sizes default to MXU-aligned 128.  GQA is handled
in the BlockSpec index maps (query head h reads kv head h // group).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _step():
        q = q_ref[0].astype(jnp.float32)  # [bq, d]
        k = k_ref[0].astype(jnp.float32)  # [bk, d]
        v = v_ref[0].astype(jnp.float32)  # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]
        if causal:
            q_pos = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            k_pos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            mask = q_pos >= k_pos
            s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[...][:, 0]
        l_prev = l_ref[...][:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        if causal:
            p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new[:, None]
        l_ref[...] = l_new[:, None]

    if causal:
        # skip kv blocks strictly above the diagonal
        pl.when(j * block_k <= i * block_q + block_q - 1)(_step)
    else:
        _step()

    @pl.when(j == num_k_blocks - 1)
    def _finalize():
        l = l_ref[...][:, 0]
        safe_l = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # [B, H, Lq, D]
    k: jnp.ndarray,  # [B, Hkv, Lk, D]
    v: jnp.ndarray,  # [B, Hkv, Lk, D]
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """FlashAttention forward with grouped KV heads. Returns [B, H, Lq, D]."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, h, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    if h % hkv:
        raise ValueError(f"H={h} not a multiple of Hkv={hkv}")
    group = h // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    if lq % block_q or lk % block_k:
        raise ValueError("seq lengths must divide block sizes")
    qf = q.reshape(b * h, lq, d)
    kf = k.reshape(b * hkv, lk, d)
    vf = v.reshape(b * hkv, lk, d)
    num_k_blocks = lk // block_k
    grid = (b * h, lq // block_q, num_k_blocks)

    def kv_index(bh, i, j):
        batch = bh // h
        head = bh % h
        return (batch * hkv + head // group, j, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale,
            causal=causal,
            block_q=block_q,
            block_k=block_k,
            num_k_blocks=num_k_blocks,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, lq, d)
