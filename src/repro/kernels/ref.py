"""Pure-jnp oracles for every Pallas kernel (the correctness references).

One oracle per kernel module:

  * ``histogram_ref``     — ``kernels.histogram``
  * ``cms_update_ref``    — ``kernels.sketch_update``
  * ``fused_ingest_ref``  — ``kernels.ingest_fused``
  * ``block_join_ref`` / ``tiled_join_ref`` — ``kernels.block_join``
  * ``attention_ref``     — ``kernels.flash_attention``
  * ``wkv6_ref``          — ``kernels.wkv6`` (defined beside its kernel for
    its scan-lowering notes; re-exported here so every oracle has one home)
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from .wkv6 import wkv6_ref  # noqa: F401  (re-export, see module docstring)


def histogram_ref(values: jnp.ndarray, num_bins: int) -> jnp.ndarray:
    """Counts of v in [0, num_bins); negatives ignored."""
    v = values.astype(jnp.int32)
    ok = v >= 0
    clipped = jnp.clip(v, 0, num_bins - 1)
    return (
        jnp.zeros(num_bins, jnp.int32)
        .at[clipped]
        .add(ok.astype(jnp.int32))
    )


def cms_update_ref(
    values: jnp.ndarray, seeds: tuple[int, ...], width: int
) -> jnp.ndarray:
    """[depth, width] bucket counts via the mix32 row family (all values valid)."""
    out = []
    for seed in seeds:
        x = values.astype(jnp.uint32) ^ jnp.uint32(seed)
        x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
        x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
        x = x ^ (x >> 16)
        bucket = (x % jnp.uint32(width)).astype(jnp.int32)
        out.append(histogram_ref(bucket, width))
    return jnp.stack(out)


def fused_ingest_ref(
    rows: jnp.ndarray,  # [N, arity] int32
    routes: tuple = (),
    sketch_cols: tuple[int, ...] = (),
    seeds: tuple[int, ...] = (),
    width: int = 2048,
    num_reducers: int = 1,
):
    """Oracle for ``kernels.ingest_fused``: (dest, rank, counts, cms).

    dest mirrors ``mapreduce.keys.map_phase``; rank is the stable-sort
    rank of each valid emission within its destination (flat emission
    order); counts is the per-reducer arrival histogram; cms stacks
    ``cms_update_ref`` over the sketched columns.
    """
    n = rows.shape[0]
    rows = rows.astype(jnp.int32)
    dest = rank = counts = cms = None
    if routes:
        blocks = []
        for offset, hashed, rep, pins, excludes in routes:
            base = jnp.full((n,), offset, jnp.int32)
            for col, seed, dim, stride in hashed:
                x = rows[:, col].astype(jnp.uint32) ^ jnp.uint32(seed)
                x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
                x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
                x = x ^ (x >> 16)
                base = base + (x % jnp.uint32(dim)).astype(jnp.int32) * jnp.int32(
                    stride
                )
            ok = jnp.ones((n,), bool)
            for col, value in pins:
                ok &= rows[:, col] == value
            for col, values in excludes:
                bad = jnp.zeros((n,), bool)
                for hv in values:
                    bad |= rows[:, col] == hv
                ok &= ~bad
            for r_off in rep:
                blocks.append(
                    jnp.where(ok, base + jnp.int32(r_off), jnp.int32(-1))
                )
        dest = jnp.stack(blocks, axis=1) if blocks else jnp.zeros((n, 0), jnp.int32)
        flat = dest.reshape(-1)
        order = jnp.argsort(flat, stable=True)
        fs = flat[order]
        first = jnp.searchsorted(fs, fs, side="left")
        rk = jnp.arange(fs.size, dtype=jnp.int32) - first.astype(jnp.int32)
        rank_flat = jnp.zeros_like(flat).at[order].set(rk)
        rank = jnp.where(dest >= 0, rank_flat.reshape(dest.shape), -1)
        counts = histogram_ref(flat, num_reducers)
    if sketch_cols:
        cms = jnp.stack(
            [cms_update_ref(rows[:, c], tuple(seeds), width) for c in sketch_cols]
        )
    return dest, rank, counts, cms


def block_join_ref(
    r_keys: jnp.ndarray,  # [K, cap_r, C]
    r_weights: jnp.ndarray,  # [K, cap_r]
    s_keys: jnp.ndarray,  # [K, cap_s, C]
    s_weights: jnp.ndarray,  # [K, cap_s]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    eq = jnp.ones((r_keys.shape[0], r_keys.shape[1], s_keys.shape[1]), bool)
    for c in range(r_keys.shape[2]):
        eq &= r_keys[:, :, c][:, :, None] == s_keys[:, :, c][:, None, :]
    eq &= (r_weights > 0)[:, :, None] & (s_weights > 0)[:, None, :]
    cnt = eq.astype(jnp.int32).sum(axis=(1, 2))
    prod = r_weights[:, :, None].astype(jnp.int32) * s_weights[:, None, :].astype(jnp.int32)
    chk = jnp.where(eq, prod, 0).sum(axis=(1, 2))
    return cnt, chk


def tiled_join_ref(
    r_keys: jnp.ndarray, r_weights: jnp.ndarray,
    s_keys: jnp.ndarray, s_weights: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    cnt, chk = block_join_ref(
        r_keys[None], r_weights[None], s_keys[None], s_weights[None]
    )
    return cnt[0], chk[0]


def attention_ref(
    q: jnp.ndarray,  # [B, H, Lq, D]
    k: jnp.ndarray,  # [B, Hkv, Lk, D]
    v: jnp.ndarray,
    causal: bool = True,
    scale: float | None = None,
) -> jnp.ndarray:
    b, h, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    group = h // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((lq, lk), bool), k=lk - lq)
        s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
