"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import math

import jax.numpy as jnp


def histogram_ref(values: jnp.ndarray, num_bins: int) -> jnp.ndarray:
    """Counts of v in [0, num_bins); negatives ignored."""
    v = values.astype(jnp.int32)
    ok = v >= 0
    clipped = jnp.clip(v, 0, num_bins - 1)
    return (
        jnp.zeros(num_bins, jnp.int32)
        .at[clipped]
        .add(ok.astype(jnp.int32))
    )


def cms_update_ref(
    values: jnp.ndarray, seeds: tuple[int, ...], width: int
) -> jnp.ndarray:
    """[depth, width] bucket counts via the mix32 row family (all values valid)."""
    out = []
    for seed in seeds:
        x = values.astype(jnp.uint32) ^ jnp.uint32(seed)
        x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
        x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
        x = x ^ (x >> 16)
        bucket = (x % jnp.uint32(width)).astype(jnp.int32)
        out.append(histogram_ref(bucket, width))
    return jnp.stack(out)


def block_join_ref(
    r_keys: jnp.ndarray,  # [K, cap_r, C]
    r_weights: jnp.ndarray,  # [K, cap_r]
    s_keys: jnp.ndarray,  # [K, cap_s, C]
    s_weights: jnp.ndarray,  # [K, cap_s]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    eq = jnp.ones((r_keys.shape[0], r_keys.shape[1], s_keys.shape[1]), bool)
    for c in range(r_keys.shape[2]):
        eq &= r_keys[:, :, c][:, :, None] == s_keys[:, :, c][:, None, :]
    eq &= (r_weights > 0)[:, :, None] & (s_weights > 0)[:, None, :]
    cnt = eq.astype(jnp.int32).sum(axis=(1, 2))
    prod = r_weights[:, :, None].astype(jnp.int32) * s_weights[:, None, :].astype(jnp.int32)
    chk = jnp.where(eq, prod, 0).sum(axis=(1, 2))
    return cnt, chk


def tiled_join_ref(
    r_keys: jnp.ndarray, r_weights: jnp.ndarray,
    s_keys: jnp.ndarray, s_weights: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    cnt, chk = block_join_ref(
        r_keys[None], r_weights[None], s_keys[None], s_weights[None]
    )
    return cnt[0], chk[0]


def attention_ref(
    q: jnp.ndarray,  # [B, H, Lq, D]
    k: jnp.ndarray,  # [B, Hkv, Lk, D]
    v: jnp.ndarray,
    causal: bool = True,
    scale: float | None = None,
) -> jnp.ndarray:
    b, h, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    group = h // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((lq, lk), bool), k=lk - lq)
        s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
