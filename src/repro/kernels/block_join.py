"""Pallas TPU kernel: reduce-phase block equi-join (count + checksum)
(DESIGN.md §2; jnp oracles: ``kernels.ref.block_join_ref`` and
``kernels.ref.tiled_join_ref``).

The per-reducer join of the SharesSkew reduce phase: instead
of a hash table (random access is hostile to VMEM/VPU), each reducer's R and
S bins are compared block-against-block — a dense [cap_r, cap_s] equality
matrix per reducer, reduced to a match count and an orderless weighted
checksum (sum of w_r * w_s over matches, int32 wraparound = mod 2^32).

Validity convention: weight 0 marks an invalid (padding) slot; valid tuples
always carry weight >= 1 (see ``repro.mapreduce.hashing.row_weight_*``).

Grid: one step per reducer; key blocks support C key columns (C static).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_join_kernel(rk_ref, rw_ref, sk_ref, sw_ref, cnt_ref, chk_ref):
    rk = rk_ref[0]  # [cap_r, C]
    sk = sk_ref[0]  # [cap_s, C]
    rw = rw_ref[0]  # [cap_r]
    sw = sw_ref[0]  # [cap_s]
    eq = jnp.ones((rk.shape[0], sk.shape[0]), dtype=bool)
    for c in range(rk.shape[1]):
        eq &= rk[:, c][:, None] == sk[:, c][None, :]
    eq &= (rw > 0)[:, None] & (sw > 0)[None, :]
    cnt_ref[0] = eq.astype(jnp.int32).sum()
    prod = rw[:, None] * sw[None, :]
    chk_ref[0] = jnp.where(eq, prod, 0).sum()


def block_join_pallas(
    r_keys: jnp.ndarray,  # [K, cap_r, C] int32
    r_weights: jnp.ndarray,  # [K, cap_r] int32 (0 = invalid slot)
    s_keys: jnp.ndarray,  # [K, cap_s, C] int32
    s_weights: jnp.ndarray,  # [K, cap_s] int32
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-reducer match counts [K] and checksums [K] (int32 wraparound)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    k, cap_r, c = r_keys.shape
    _, cap_s, _ = s_keys.shape
    grid = (k,)
    return pl.pallas_call(
        _block_join_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cap_r, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, cap_r), lambda i: (i, 0)),
            pl.BlockSpec((1, cap_s, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, cap_s), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.int32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
        ],
        interpret=interpret,
    )(
        r_keys.astype(jnp.int32),
        r_weights.astype(jnp.int32),
        s_keys.astype(jnp.int32),
        s_weights.astype(jnp.int32),
    )


def _tiled_join_kernel(rk_ref, rw_ref, sk_ref, sw_ref, cnt_ref, chk_ref):
    """Large-N variant: 2-D tile grid over one flat (R, S) pair, scalar
    accumulators revisited every step (for the non-binned paper workloads
    where one reducer handles millions of tuples)."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        chk_ref[...] = jnp.zeros_like(chk_ref)

    rk = rk_ref[...]  # [bn, C]
    sk = sk_ref[...]  # [bm, C]
    rw = rw_ref[...]
    sw = sw_ref[...]
    eq = jnp.ones((rk.shape[0], sk.shape[0]), dtype=bool)
    for c in range(rk.shape[1]):
        eq &= rk[:, c][:, None] == sk[:, c][None, :]
    eq &= (rw > 0)[:, None] & (sw > 0)[None, :]
    cnt_ref[...] += eq.astype(jnp.int32).sum()
    chk_ref[...] += jnp.where(eq, rw[:, None] * sw[None, :], 0).sum()


def tiled_join_pallas(
    r_keys: jnp.ndarray,  # [N, C]
    r_weights: jnp.ndarray,  # [N]
    s_keys: jnp.ndarray,  # [M, C]
    s_weights: jnp.ndarray,  # [M]
    block_n: int = 512,
    block_m: int = 512,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single flat join: returns (count, checksum) scalars."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    def _pad(keys, w, b):
        n = keys.shape[0]
        pad = (-n) % b
        if pad:
            keys = jnp.concatenate(
                [keys, jnp.zeros((pad, keys.shape[1]), keys.dtype)]
            )
            w = jnp.concatenate([w, jnp.zeros(pad, w.dtype)])
        return keys, w

    block_n = min(block_n, max(int(r_keys.shape[0]), 1))
    block_m = min(block_m, max(int(s_keys.shape[0]), 1))
    r_keys, r_weights = _pad(r_keys, r_weights, block_n)
    s_keys, s_weights = _pad(s_keys, s_weights, block_m)
    c = r_keys.shape[1]
    grid = (r_keys.shape[0] // block_n, s_keys.shape[0] // block_m)
    cnt, chk = pl.pallas_call(
        _tiled_join_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, c), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_m, c), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(
        r_keys.astype(jnp.int32),
        r_weights.astype(jnp.int32),
        s_keys.astype(jnp.int32),
        s_weights.astype(jnp.int32),
    )
    return cnt[0], chk[0]
