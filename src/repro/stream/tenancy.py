"""Multi-tenant streaming joins: N queries, one ingest path (DESIGN.md §9).

A production deployment does not run one join query per process: many
concurrent queries watch the *same* relation streams, and the expensive
shared work — sketching the inflow for heavy hitters — is identical for
every query that shares a sketch configuration.  ``MultiQueryEngine`` runs
N ``StreamingJoinEngine``s behind one ingest call with three contracts:

  * **Shared sketch ingest.**  Count-Min increments are computed ONCE per
    relation batch per sketch signature (width, depth, seed) and absorbed
    by every eligible tenant (``sketch.cms_delta`` → ``ingest(...,
    shared_deltas=...)``).  Integer counts are exact in float64, so the
    absorbed tables are bit-identical to a private pass; a tenant whose
    admitted rows differ from the shared batch (backlog, shedding, a
    tampered view) silently falls back to a private pass — correctness
    never depends on the sharing.  ``shared_sketch_passes`` /
    ``engine.sketch_ingest_calls`` count both sides of that contract.
  * **Blast-radius containment.**  Every tenant ingests inside a per-query
    circuit breaker.  A poison batch (``engine._validate_batch`` raises
    before any state mutation) trips the breaker: the victim is
    ``QUARANTINED`` for an exponentially growing backoff
    (``base * 2^(failures-1)`` batches), re-opened at most
    ``max_reopens`` times, then ``FAILED`` permanently — as it is
    immediately on ``RecoveryExhaustedError``.  A query whose recovery
    degraded its plan serves on as ``DEGRADED``.  Neighbors never see any
    of it: their engines are separate objects fed pristine views, so their
    cumulative fingerprints stay bit-identical to single-tenant runs (the
    isolation proof in ``tests/test_tenancy.py``).
  * **Fair-share overload control.**  Per batch, each tenant's demand is
    its offered rows weighted by its live plan's replication width (the
    Beame–Koutris–Suciu communication budget: what it will actually
    ship).  When aggregate demand exceeds ``TenancyPolicy.capacity``, the
    weighted max-min allocation (``admission.weighted_fair_allocation``)
    trims ONLY tenants over their fair share — trimmed rows are shed at
    the door with exact per-tenant counters (``overload_shed``,
    ``backpressure``) and the offender's own FIFO admission sees the rest.

Host faults route through the same recovery subsystem as single-tenant
engines, scoped per query: each tenant's engine has its own ``HostTracker``
and lineage, so a tenant-targeted ``host_loss`` replays/degrades the
victim alone.  Checkpoints are per-tenant namespaced directories
(``train.checkpoint.tenant_checkpoint_dir``) plus one control namespace
for breaker and fair-share state — kill → resume is bit-identical for
every tenant.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Mapping

import numpy as np

from repro.core.schema import JoinQuery
from repro.obs import Observability, ObsPolicy

from .admission import FairShareController, replication_width
from .engine import BatchReport, StreamConfig, StreamingJoinEngine
from .recovery import RecoveryExhaustedError
from .sketch import cms_delta

# tenant lifecycle states
RUNNING = "RUNNING"
QUARANTINED = "QUARANTINED"  # breaker open; ingest skipped until reopen
DEGRADED = "DEGRADED"  # serving, but on a repaired (shrunk) plan
FAILED = "FAILED"  # breaker exhausted or recovery exhausted; terminal

_CONTROL = "__control__"  # reserved checkpoint namespace (not a tenant)


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One query's identity, plan inputs, and fair-share weight."""

    name: str
    query: JoinQuery
    config: StreamConfig
    weight: float = 1.0

    def __post_init__(self):
        if not self.name or not all(
            c.isalnum() or c in "-_." for c in self.name
        ):
            raise ValueError(
                f"tenant name {self.name!r} must be a filename-safe token"
            )
        if self.name == _CONTROL:
            raise ValueError(f"tenant name {_CONTROL!r} is reserved")
        if not (self.weight > 0 and np.isfinite(self.weight)):
            raise ValueError(f"tenant weight must be finite > 0, got {self.weight}")


@dataclasses.dataclass(frozen=True)
class TenancyPolicy:
    """Engine-wide knobs (defaults: no aggregate cap, 3 reopens)."""

    capacity: float | None = None  # aggregate predicted arrivals per batch
    #                                (None = no cross-tenant shedding)
    breaker_backoff: int = 1  # quarantine length after the 1st failure
    #                           (doubles per consecutive failure)
    breaker_max_reopens: int = 3  # reopen attempts before FAILED
    # Observability (DESIGN.md §10): ONE tracer + metrics registry shared
    # by all tenants; each tenant engine gets a label-injecting view, so
    # the same metric name yields per-tenant isolated series.  A tenant's
    # own ``StreamConfig.obs`` is ignored under a MultiQueryEngine — the
    # shared facade wins (injected obs takes precedence in the engine).
    obs: ObsPolicy = ObsPolicy()

    def __post_init__(self):
        if self.breaker_backoff < 1:
            raise ValueError("breaker_backoff must be >= 1 batch")
        if self.breaker_max_reopens < 0:
            raise ValueError("breaker_max_reopens must be >= 0")


@dataclasses.dataclass(frozen=True)
class TenantStatus:
    """Externally visible snapshot of one tenant's breaker."""

    name: str
    state: str
    failures: int  # consecutive breaker trips (resets on a good batch)
    reopens: int  # reopen attempts consumed (never resets)
    quarantined_until: int  # shared batch index at which the breaker half-opens
    last_error: str


class _Tenant:
    """Runtime record: spec + engine + circuit breaker."""

    def __init__(self, spec: TenantSpec, engine: StreamingJoinEngine):
        self.spec = spec
        self.engine = engine
        self.state = RUNNING
        self.failures = 0
        self.reopens = 0
        self.quarantined_until = 0
        self.last_error = ""

    def status(self) -> TenantStatus:
        return TenantStatus(
            name=self.spec.name,
            state=self.state,
            failures=self.failures,
            reopens=self.reopens,
            quarantined_until=self.quarantined_until,
            last_error=self.last_error,
        )


class MultiQueryEngine:
    """N concurrent join queries over shared relation streams."""

    def __init__(
        self,
        tenants: Iterable[TenantSpec],
        policy: TenancyPolicy = TenancyPolicy(),
        log_fn: Callable[[str], None] | None = None,
        clock: Callable[[], float] | None = None,
    ):
        specs = list(tenants)
        if not specs:
            raise ValueError("MultiQueryEngine needs at least one tenant")
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {sorted(names)}")
        self.policy = policy
        self._log = log_fn or (lambda _msg: None)
        self.obs = Observability(policy.obs)  # shared tracer + registry
        self._tenants: dict[str, _Tenant] = {}
        for spec in specs:
            engine = StreamingJoinEngine(
                spec.query,
                spec.config,
                log_fn=log_fn,
                clock=clock,
                obs=self.obs.for_tenant(
                    spec.name,
                    arities={r.name: r.arity for r in spec.query.relations},
                ),
            )
            engine.tenant = spec.name
            self._tenants[spec.name] = _Tenant(spec, engine)
        self.fair = FairShareController(
            policy.capacity, {s.name: s.weight for s in specs}
        )
        self._injector = None
        self.batches = 0  # shared batch clock (absolute index)
        # sketch sharing: one pass per relation batch per sketch signature
        self.shared_sketch_passes = 0  # (attr, rel) column passes computed
        self._sketch_groups = self._group_sketches()

    # ---- shared sketch ingest ----------------------------------------------
    def _group_sketches(self) -> list[tuple[tuple[int, ...], int, list[str], dict]]:
        """Group tenants by CMS signature (seeds, width): one shared pass
        per group covers the union of its members' (attr, rel) columns."""
        groups: dict[tuple, dict] = {}
        for t in self._tenants.values():
            tr = t.engine.tracker
            key = (tr.seeds, tr.width)
            g = groups.setdefault(key, {"members": [], "cols": {}})
            g["members"].append(t.spec.name)
            for a in tr.attrs:
                for rel in t.spec.query.relations_of(a):
                    g["cols"][(a, rel.name)] = rel.index_of(a)
        return [
            (seeds, width, g["members"], g["cols"])
            for (seeds, width), g in sorted(
                groups.items(), key=lambda kv: kv[1]["members"]
            )
        ]

    def _shared_deltas(
        self, batch: Mapping[str, np.ndarray]
    ) -> dict[str, dict[tuple[str, str], np.ndarray]]:
        """The once-per-relation-batch sketch pass: per tenant name, the
        delta dict its engine can absorb (same object shared across the
        group — computed once, never mutated by absorb)."""
        per_tenant: dict[str, dict[tuple[str, str], np.ndarray]] = {}
        for seeds, width, members, cols in self._sketch_groups:
            deltas: dict[tuple[str, str], np.ndarray] = {}
            for (a, rel_name), col_idx in sorted(cols.items()):
                if rel_name not in batch:
                    continue
                rows = np.asarray(batch[rel_name])
                if rows.ndim != 2 or col_idx >= rows.shape[1]:
                    continue  # malformed shared batch; tenants will reject
                deltas[(a, rel_name)] = cms_delta(
                    rows[:, col_idx], seeds, width
                )
                self.shared_sketch_passes += 1
                if self.obs.metrics.enabled:
                    self.obs.counter("tenancy_shared_sketch_passes_total").inc()
            for name in members:
                per_tenant[name] = deltas
        return per_tenant

    # ---- fair share --------------------------------------------------------
    def _demand(self, t: _Tenant, view: Mapping[str, np.ndarray]) -> float:
        """Predicted reducer arrivals this tenant's view will generate:
        rows x replication width per relation (width 1 pre-plan)."""
        plan = t.engine.plan
        total = 0.0
        for rel in t.spec.query.relations:
            n = len(view.get(rel.name, ()))
            w = replication_width(plan, rel.name) if plan is not None else 1
            total += float(n) * w
        return total

    @staticmethod
    def _trim(
        view: dict[str, np.ndarray], fraction: float
    ) -> tuple[dict[str, np.ndarray], int]:
        """Keep the FIFO prefix of ``fraction`` of each relation's rows;
        returns (trimmed view, rows dropped)."""
        if fraction >= 1.0:
            return view, 0
        out, dropped = {}, 0
        for nm, rows in view.items():
            rows = np.asarray(rows)
            keep = int(np.floor(rows.shape[0] * fraction))
            out[nm] = rows[:keep]
            dropped += rows.shape[0] - keep
        return out, dropped

    # ---- circuit breaker ---------------------------------------------------
    def _state_event(self, name: str, to_state: str, bid: int) -> None:
        """One breaker/lifecycle transition into the shared registry + trace
        (DESIGN.md §10).  Labeled (tenant, to), so a scrape sees each
        tenant's transition history as its own series."""
        if self.obs.metrics.enabled:
            self.obs.counter(
                "tenancy_breaker_transitions_total", tenant=name, to=to_state
            ).inc()
        if self.obs.tracer.enabled:
            self.obs.instant(
                "tenant.state",
                cat="tenancy",
                args={"tenant": name, "to": to_state, "batch": bid},
            )

    def _trip(self, t: _Tenant, bid: int, err: BaseException) -> None:
        """One breaker trip: quarantine with exponential backoff, or FAIL
        permanently once the reopen budget is spent."""
        t.failures += 1
        t.last_error = f"{type(err).__name__}: {err}"
        if t.reopens >= self.policy.breaker_max_reopens:
            t.state = FAILED
            self._state_event(t.spec.name, FAILED, bid)
            self._log(
                f"[tenancy] {t.spec.name} FAILED at batch {bid}: reopen "
                f"budget spent after {t.failures} failure(s) ({t.last_error})"
            )
            return
        backoff = self.policy.breaker_backoff * (2 ** (t.failures - 1))
        t.state = QUARANTINED
        t.quarantined_until = bid + 1 + backoff
        self._state_event(t.spec.name, QUARANTINED, bid)
        self._log(
            f"[tenancy] {t.spec.name} QUARANTINED at batch {bid} for "
            f"{backoff} batch(es) ({t.last_error})"
        )

    def _maybe_reopen(self, t: _Tenant, bid: int) -> None:
        if t.state == QUARANTINED and bid >= t.quarantined_until:
            t.reopens += 1
            t.state = RUNNING
            self._state_event(t.spec.name, RUNNING, bid)
            self._log(
                f"[tenancy] {t.spec.name} breaker half-open at batch {bid} "
                f"(reopen {t.reopens}/{self.policy.breaker_max_reopens})"
            )

    # ---- ingest ------------------------------------------------------------
    def ingest(
        self, batch: Mapping[str, np.ndarray]
    ) -> dict[str, BatchReport | None]:
        """One shared micro-batch through every serving tenant.

        Returns per tenant: its ``BatchReport``, or ``None`` when the
        tenant did not serve this batch (quarantined, failed, or tripped
        on it).  The shared batch object is never mutated — every tenant
        reads its own view.
        """
        bid = self.batches
        for t in self._tenants.values():
            self._maybe_reopen(t, bid)
        serving = [
            t
            for t in self._tenants.values()
            if t.state in (RUNNING, DEGRADED)
        ]

        # per-tenant views: restriction to the query's relations, then
        # tenant-targeted fault tampering (victim's view only)
        views: dict[str, dict[str, np.ndarray]] = {}
        events: dict[str, list] = {}
        clean: dict[str, bool] = {}
        for t in serving:
            nm = t.spec.name
            view = {
                r.name: batch[r.name]
                for r in t.spec.query.relations
                if r.name in batch
            }
            clean[nm] = True
            events[nm] = []
            if self._injector is not None:
                view, evs = self._injector.apply_tenant_faults(bid, nm, view)
                if evs:
                    events[nm] = evs
                    clean[nm] = False
            views[nm] = view

        # fair-share overload control over the (possibly inflated) demand
        demands = {t.spec.name: self._demand(t, views[t.spec.name]) for t in serving}
        fractions = self.fair.fractions(demands)
        for t in serving:
            nm = t.spec.name
            views[nm], dropped = self._trim(views[nm], fractions.get(nm, 1.0))
            if self.obs.metrics.enabled:
                self.obs.gauge("tenancy_fair_fraction", tenant=nm).set(
                    fractions.get(nm, 1.0)
                )
                self.obs.gauge("tenancy_demand_rows", tenant=nm).set(
                    demands.get(nm, 0.0)
                )
            if dropped:
                self.fair.record_trim(nm, dropped)
                clean[nm] = False  # admitted view != shared batch
                if self.obs.metrics.enabled:
                    self.obs.counter(
                        "tenancy_overload_shed_rows_total", tenant=nm
                    ).inc(dropped)
                self._log(
                    f"[tenancy] {nm} overload-shed {dropped} row(s) at "
                    f"batch {bid} (fair share {fractions[nm]:.3f})"
                )

        # the ONE shared sketch pass per relation batch
        shared = self._shared_deltas(batch)

        out: dict[str, BatchReport | None] = {
            name: None for name in self._tenants
        }
        for t in serving:
            nm = t.spec.name
            try:
                out[nm] = t.engine.ingest(
                    views[nm],
                    shared_deltas=shared.get(nm) if clean[nm] else None,
                )
                if t.failures:
                    t.failures = 0  # breaker closes on a good batch
                if t.state == RUNNING and any(
                    r.mode == "degrade" for r in t.engine.recoveries
                ):
                    t.state = DEGRADED
                    self._state_event(nm, DEGRADED, bid)
            except RecoveryExhaustedError as err:
                t.state = FAILED
                t.last_error = f"{type(err).__name__}: {err}"
                self._state_event(nm, FAILED, bid)
                self._log(
                    f"[tenancy] {nm} FAILED at batch {bid}: {t.last_error}"
                )
            except Exception as err:  # poison pill / schema mismatch
                self._trip(t, bid, err)
            # tenant-targeted events are contained iff the engine either
            # served the tampered view with exact counters (overload) or
            # the breaker took the victim out (poison)
            for ev in events[nm]:
                from repro.testing.faults import FaultInjector

                if ev.spec.kind == "tenant_overload":
                    contained = out[nm] is not None or t.state in (
                        QUARANTINED,
                        FAILED,
                    )
                else:  # poison_rows: containment == the breaker acted
                    contained = out[nm] is None and t.state in (
                        QUARANTINED,
                        FAILED,
                    )
                FaultInjector.mark_tenant_event(ev, contained)
        self.batches += 1
        return out

    # ---- faults / recovery -------------------------------------------------
    def arm_faults(self, injector) -> None:
        """Attach one ``FaultInjector`` for every seam: tenant-targeted
        batch tampering here, host faults inside each tenant's engine
        (scoped by ``engine.tenant``, so a targeted loss fires only in the
        victim's recovery domain)."""
        self._injector = injector
        for t in self._tenants.values():
            t.engine.arm_faults(injector)

    def fail_hosts(self, tenant: str, hosts_to_kill):
        """Operational host kill inside ONE tenant's recovery domain; a
        recovery-exhausted victim is contained as FAILED instead of
        propagating (the neighbors keep serving).  Returns the victim's
        ``RecoveryReport`` (None if nothing recovered or the tenant
        failed)."""
        t = self._tenant(tenant)
        try:
            report = t.engine.fail_hosts(hosts_to_kill)
            if t.state == RUNNING and any(
                r.mode == "degrade" for r in t.engine.recoveries
            ):
                t.state = DEGRADED
                self._state_event(tenant, DEGRADED, self.batches)
            return report
        except RecoveryExhaustedError as err:
            t.state = FAILED
            t.last_error = f"{type(err).__name__}: {err}"
            self._state_event(tenant, FAILED, self.batches)
            self._log(f"[tenancy] {tenant} FAILED on host kill: {t.last_error}")
            return None

    # ---- introspection -----------------------------------------------------
    def _tenant(self, name: str) -> _Tenant:
        if name not in self._tenants:
            raise KeyError(f"unknown tenant {name!r}")
        return self._tenants[name]

    def engine(self, name: str) -> StreamingJoinEngine:
        return self._tenant(name).engine

    def status(self) -> dict[str, TenantStatus]:
        return {nm: t.status() for nm, t in self._tenants.items()}

    def serving(self) -> list[str]:
        return sorted(
            nm
            for nm, t in self._tenants.items()
            if t.state in (RUNNING, DEGRADED)
        )

    # ---- checkpoint (DESIGN.md §9) -----------------------------------------
    _STATE_CODES = {RUNNING: 0, QUARANTINED: 1, DEGRADED: 2, FAILED: 3}

    def save_checkpoint(self, directory: str, keep: int = 3) -> None:
        """Every tenant engine into its own namespace, plus one control
        namespace for the breaker + fair-share state.  Each namespace uses
        the atomic step/LATEST layout, so a kill at ANY point leaves every
        tenant restorable (at worst one batch behind its neighbors)."""
        from repro.train.checkpoint import (
            save_checkpoint as _save,
            tenant_checkpoint_dir,
        )

        for nm, t in self._tenants.items():
            t.engine.save_checkpoint(
                tenant_checkpoint_dir(directory, nm), keep=keep
            )
        codes = {nm: self._STATE_CODES[t.state] for nm, t in self._tenants.items()}
        names = sorted(self._tenants)
        tree = {
            "batches": np.array([self.batches], np.int64),
            "breaker": np.array(
                [
                    [
                        codes[nm],
                        self._tenants[nm].failures,
                        self._tenants[nm].reopens,
                        self._tenants[nm].quarantined_until,
                    ]
                    for nm in names
                ],
                np.int64,
            ),
        }
        tree.update(
            {f"fair/{k}": v for k, v in self.fair.state_dict().items()}
        )
        _save(
            tenant_checkpoint_dir(directory, _CONTROL),
            step=self.batches,
            tree=tree,
            keep=keep,
            metadata={"tenants": names},
        )

    @classmethod
    def restore(
        cls,
        directory: str,
        tenants: Iterable[TenantSpec],
        policy: TenancyPolicy = TenancyPolicy(),
        log_fn: Callable[[str], None] | None = None,
        clock: Callable[[], float] | None = None,
    ) -> "MultiQueryEngine":
        """Rebuild every tenant bit-identically from its namespace."""
        from repro.train.checkpoint import (
            load_checkpoint,
            load_manifest,
            tenant_checkpoint_dir,
        )

        specs = list(tenants)
        # validate the tenant set against the control manifest FIRST, so a
        # spec/checkpoint mismatch fails loudly before any engine loads
        ctrl = tenant_checkpoint_dir(directory, _CONTROL)
        manifest = load_manifest(ctrl)
        saved_names = manifest["metadata"]["tenants"]
        if saved_names != sorted(s.name for s in specs):
            raise ValueError(
                f"checkpoint tenants {saved_names} != restore specs "
                f"{sorted(s.name for s in specs)}"
            )
        out = cls.__new__(cls)
        out.policy = policy
        out._log = log_fn or (lambda _msg: None)
        out.obs = Observability(policy.obs)  # fresh shared tracer+registry
        out._tenants = {}
        for spec in specs:
            engine = StreamingJoinEngine.restore(
                tenant_checkpoint_dir(directory, spec.name),
                spec.query,
                spec.config,
                log_fn=log_fn,
                clock=clock,
                obs=out.obs.for_tenant(
                    spec.name,
                    arities={r.name: r.arity for r in spec.query.relations},
                ),
            )
            engine.tenant = spec.name
            out._tenants[spec.name] = _Tenant(spec, engine)
        out.fair = FairShareController(
            policy.capacity, {s.name: s.weight for s in specs}
        )
        out._injector = None
        out.shared_sketch_passes = 0
        out._sketch_groups = out._group_sketches()

        _, flat = load_checkpoint(ctrl)
        out.batches = int(np.asarray(flat["batches"])[0])
        code_to_state = {v: k for k, v in cls._STATE_CODES.items()}
        breaker = np.asarray(flat["breaker"])
        for i, nm in enumerate(saved_names):
            t = out._tenants[nm]
            t.state = code_to_state[int(breaker[i, 0])]
            t.failures = int(breaker[i, 1])
            t.reopens = int(breaker[i, 2])
            t.quarantined_until = int(breaker[i, 3])
        out.fair.load_state_dict(
            {
                "shed": flat["fair/shed"],
                "backpressure": flat["fair/backpressure"],
            }
        )
        return out
