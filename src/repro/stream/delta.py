"""Merge-join delta state for the fused ingest path (DESIGN.md §7).

The general incremental join in ``engine._delta_join`` evaluates each
telescoping term with the dense per-reducer einsum of
``mapreduce.local_join.local_join_count_checksum``.  That einsum pads every
reducer to the capacity of the *hottest* bin, so under skew (the whole point
of SharesSkew) each batch pays O(K * cap_state * cap_batch) — quadratic in
stream length and, worse, proportional to padding that holds no tuples.

For the dominant streaming case — two relations joined on a single shared
column — the same sums collapse to an order-free contraction over exact
key groups:

    count_term = |{(a, b) : dest_a = dest_b, val_a = val_b}|
    chk_term   = sum over those pairs of w_a * w_b   (mod 2^32)

Both are computed exactly from a per-relation array of emissions sorted by
the composite key ``dest << 32 | joinval``: ``searchsorted`` finds each
probe's group, and prefix sums of counts / mod-2^32 weights finish the
contraction in O((M + E) log M).  Integer sums are order-independent and
uint32 arithmetic wraps exactly like the int32 einsum accumulation, so the
result is bit-identical to the einsum path — this is an *algorithmic*
re-association of the very same sum, not an approximation.

The index is maintained incrementally: appending a batch is a host-side
sorted merge (O(M + E) memcpy), never a re-sort of history; only a replan
rebuilds it from scratch, mirroring how ``engine`` treats its binned state.
Queries with >2 relations or multi-column links keep the einsum path.

Each entry carries the id of the batch that contributed it, so windowed
retention (DESIGN.md §8) can ``expire`` one batch's emissions exactly — a
boolean-mask compaction over the retained arrays, O(M), no re-sort and no
re-route.  Expiry plus the engine's retraction probes keep the windowed
fingerprint bit-identical to the einsum path on the retained suffix.
"""
from __future__ import annotations

import numpy as np

from repro.mapreduce.hashing import row_weight_np
from repro.mapreduce.local_join import LocalJoinSpec


def _keys(dest: np.ndarray, vals: np.ndarray) -> np.ndarray:
    """Composite sort key: reducer id in the high 32 bits, join value
    (zero-extended uint32 bit pattern) in the low 32."""
    return (dest.astype(np.int64) << 32) | vals.astype(np.uint32).astype(np.int64)


class SortedDeltaIndex:
    """Per-relation sorted emission index for exact merge-join deltas.

    Holds, per relation, the flat routed emissions of the accumulated
    stream sorted by ``(dest, join_value)`` with their mod-2^32 row
    weights aligned.  ``probe`` evaluates one telescoping term against a
    relation's current index; ``append`` folds a batch in.
    """

    @staticmethod
    def eligible(spec: LocalJoinSpec) -> bool:
        """True for binary joins with exactly one shared column."""
        return (
            len(spec.rel_names) == 2
            and len(spec.links) == 1
            and len(spec.links[0][2]) == 1
        )

    def __init__(self, spec: LocalJoinSpec, weight_seed: int = 0x5EED):
        if not self.eligible(spec):
            raise ValueError("SortedDeltaIndex requires a binary 1-column link")
        ((_, _, ((col_l, col_r),)),) = spec.links
        self.rel_names = spec.rel_names
        # join column + weight seed per relation (seed offset = index in
        # spec.rel_names, matching local_join_count_checksum exactly)
        self._col = {spec.rel_names[0]: col_l, spec.rel_names[1]: col_r}
        self._seed = {nm: weight_seed + i for i, nm in enumerate(spec.rel_names)}
        self._keys_by_rel: dict[str, np.ndarray] = {}
        self._weights_by_rel: dict[str, np.ndarray] = {}
        self._batch_by_rel: dict[str, np.ndarray] = {}  # contributing batch id
        for nm in spec.rel_names:
            self.clear(nm)

    # ---- maintenance -------------------------------------------------------
    def _flat(
        self, name: str, dest: np.ndarray, rows: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """(sorted keys, aligned weights) of one batch of emissions."""
        if dest.size == 0:
            return np.empty(0, np.int64), np.empty(0, np.uint32)
        keys = _keys(dest, rows[:, self._col[name]])
        w = row_weight_np(rows, self._seed[name]).astype(np.uint32)
        order = np.argsort(keys, kind="stable")
        return keys[order], w[order]

    def clear(self, name: str) -> None:
        """Reset a relation's index from scratch (replan migration rebuilds
        by re-appending each retained batch with its id)."""
        self._keys_by_rel[name] = np.empty(0, np.int64)
        self._weights_by_rel[name] = np.empty(0, np.uint32)
        self._batch_by_rel[name] = np.empty(0, np.int64)

    def rebuild(
        self, name: str, dest: np.ndarray, rows: np.ndarray, batch_id: int = 0
    ) -> None:
        """Reset a relation's index to exactly one batch of emissions."""
        self.clear(name)
        self.append(name, dest, rows, batch_id)

    def append(
        self, name: str, dest: np.ndarray, rows: np.ndarray, batch_id: int = 0
    ) -> None:
        """Sorted-merge a batch of emissions into a relation's index."""
        if dest.size == 0:
            return
        new_keys, new_w = self._flat(name, dest, rows)
        keys = self._keys_by_rel[name]
        pos = np.searchsorted(keys, new_keys, side="right")
        self._keys_by_rel[name] = np.insert(keys, pos, new_keys)
        self._weights_by_rel[name] = np.insert(
            self._weights_by_rel[name], pos, new_w
        )
        self._batch_by_rel[name] = np.insert(
            self._batch_by_rel[name], pos, np.int64(batch_id)
        )

    def drop_reducers(self, name: str, reducer_ids: np.ndarray) -> int:
        """Remove every entry destined for the given reducers — the index
        half of simulated reducer loss (DESIGN.md §5).  The composite key
        carries the destination in its high 32 bits, so lost entries are a
        boolean-mask compaction, exactly like ``expire``; lineage replay
        re-appends the survivors' share batch-by-batch afterwards.
        Returns the number removed."""
        reducer_ids = np.asarray(reducer_ids, dtype=np.int64)
        keys = self._keys_by_rel[name]
        if keys.size == 0 or reducer_ids.size == 0:
            return 0
        keep = ~np.isin(keys >> 32, reducer_ids)
        removed = int(keys.size - keep.sum())
        if removed:
            self._keys_by_rel[name] = keys[keep]
            self._weights_by_rel[name] = self._weights_by_rel[name][keep]
            self._batch_by_rel[name] = self._batch_by_rel[name][keep]
        return removed

    def expire(self, name: str, batch_id: int) -> int:
        """Remove every entry batch ``batch_id`` contributed to a relation's
        index (windowed retention).  Returns the number removed."""
        ids = self._batch_by_rel[name]
        keep = ids != np.int64(batch_id)
        removed = int(ids.size - keep.sum())
        if removed:
            self._keys_by_rel[name] = self._keys_by_rel[name][keep]
            self._weights_by_rel[name] = self._weights_by_rel[name][keep]
            self._batch_by_rel[name] = ids[keep]
        return removed

    # ---- the contraction ---------------------------------------------------
    def probe(
        self, name: str, probe_name: str, dest: np.ndarray, rows: np.ndarray
    ) -> tuple[int, int]:
        """Join the probe emissions (from ``probe_name``) against relation
        ``name``'s current index.  Returns (count, checksum mod 2^32) —
        bit-identical to the corresponding einsum telescoping term."""
        keys = self._keys_by_rel[name]
        w_state = self._weights_by_rel[name]
        if dest.size == 0 or keys.size == 0:
            return 0, 0
        pkeys = _keys(dest, rows[:, self._col[probe_name]])
        w_probe = row_weight_np(rows, self._seed[probe_name]).astype(np.uint32)
        lo = np.searchsorted(keys, pkeys, side="left")
        hi = np.searchsorted(keys, pkeys, side="right")
        count = int(np.sum((hi - lo).astype(np.int64)))
        wpref = np.concatenate(
            [np.zeros(1, np.uint32), np.cumsum(w_state, dtype=np.uint32)]
        )
        group_w = wpref[hi] - wpref[lo]  # uint32 wraparound, exact mod 2^32
        chk = int(np.sum(w_probe * group_w, dtype=np.uint32))
        return count, chk
