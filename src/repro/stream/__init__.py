"""Streaming SharesSkew: online micro-batch joins with drift-triggered
replanning (DESIGN.md §6; fused ingest hot path: §7; bounded state: §8).

  * ``sketch``    — decaying Count-Min + SpaceSaving heavy-hitter tracking
  * ``drift``     — cost-model staleness checks for the running plan
  * ``engine``    — stateful executor with carried reducer state; with
    ``StreamConfig(fused_ingest=True)`` the per-batch hot path runs
    through the ``kernels.ingest_fused`` Pallas pass
  * ``delta``     — sorted merge-join evaluation of the incremental-join
    terms for binary single-column joins (the fused path's delta engine)
  * ``retention`` — windowed/TTL expiry of carried state with exact
    window-fingerprint retraction
  * ``admission`` — backpressure: budgeted admission, FIFO backlog,
    explicit shedding with exact counters
"""
from .admission import (
    AdmissionController,
    AdmissionDecision,
    AdmissionPolicy,
    replication_width,
)
from .drift import DriftDecision, DriftMonitor, plan_comm_on_batch, predicted_loads
from .engine import BatchReport, StreamConfig, StreamingJoinEngine
from .retention import RetentionPolicy, carried_tuples, remove_prefix
from .sketch import DecayingCountMin, HHSnapshot, SpaceSaving, StreamHHTracker

__all__ = [
    "AdmissionController",
    "AdmissionDecision",
    "AdmissionPolicy",
    "BatchReport",
    "DecayingCountMin",
    "DriftDecision",
    "DriftMonitor",
    "HHSnapshot",
    "RetentionPolicy",
    "SpaceSaving",
    "StreamConfig",
    "StreamingJoinEngine",
    "StreamHHTracker",
    "carried_tuples",
    "plan_comm_on_batch",
    "predicted_loads",
    "remove_prefix",
    "replication_width",
]
