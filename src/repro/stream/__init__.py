"""Streaming SharesSkew: online micro-batch joins with drift-triggered
replanning (DESIGN.md §6; fused ingest hot path: §7; bounded state: §8).

  * ``sketch``    — decaying Count-Min + SpaceSaving heavy-hitter tracking
  * ``drift``     — cost-model staleness checks for the running plan
  * ``engine``    — stateful executor with carried reducer state; with
    ``StreamConfig(fused_ingest=True)`` the per-batch hot path runs
    through the ``kernels.ingest_fused`` Pallas pass
  * ``delta``     — sorted merge-join evaluation of the incremental-join
    terms for binary single-column joins (the fused path's delta engine)
  * ``retention`` — windowed/TTL expiry of carried state with exact
    window-fingerprint retraction
  * ``admission`` — backpressure: budgeted admission, FIFO backlog,
    explicit shedding with exact counters
  * ``recovery``  — reducer-loss recovery: host placement + heartbeat
    detection, lineage replay of lost reducer state, plan repair onto
    survivors, elastic degraded mode (DESIGN.md §5)
  * ``tenancy``   — multi-tenant engine: N queries behind one ingest with
    shared sketch passes, per-query circuit breakers, weighted fair-share
    overload shedding, tenant-scoped recovery (DESIGN.md §9)

Observability (``repro.obs``, DESIGN.md §10) threads through all of it:
``StreamConfig(obs=ObsPolicy(...))`` turns on nested-span tracing,
the metrics registry, and per-reducer SkewScope telemetry; the
``ObsPolicy`` re-export here keeps engine construction one import.
"""
from repro.obs import Observability, ObsPolicy  # noqa: F401  (re-export)

from .admission import (
    AdmissionController,
    AdmissionDecision,
    AdmissionPolicy,
    FairShareController,
    replication_width,
    weighted_fair_allocation,
)
from .drift import DriftDecision, DriftMonitor, plan_comm_on_batch, predicted_loads
from .engine import BatchReport, StreamConfig, StreamingJoinEngine
from .recovery import (
    HostTracker,
    RecoveryExhaustedError,
    RecoveryPolicy,
    RecoveryReport,
)
from .retention import (
    RetentionPolicy,
    carried_tuples,
    lost_occupancy,
    remove_prefix,
    select_reducers,
    zero_reducers,
)
from .sketch import (
    DecayingCountMin,
    HHSnapshot,
    SpaceSaving,
    StreamHHTracker,
    cms_delta,
)
from .tenancy import (
    DEGRADED,
    FAILED,
    QUARANTINED,
    RUNNING,
    MultiQueryEngine,
    TenancyPolicy,
    TenantSpec,
    TenantStatus,
)

__all__ = [
    "AdmissionController",
    "AdmissionDecision",
    "AdmissionPolicy",
    "BatchReport",
    "DEGRADED",
    "FAILED",
    "FairShareController",
    "MultiQueryEngine",
    "Observability",
    "ObsPolicy",
    "QUARANTINED",
    "RUNNING",
    "TenancyPolicy",
    "TenantSpec",
    "TenantStatus",
    "DecayingCountMin",
    "DriftDecision",
    "DriftMonitor",
    "HHSnapshot",
    "HostTracker",
    "RecoveryExhaustedError",
    "RecoveryPolicy",
    "RecoveryReport",
    "RetentionPolicy",
    "SpaceSaving",
    "StreamConfig",
    "StreamingJoinEngine",
    "StreamHHTracker",
    "carried_tuples",
    "cms_delta",
    "lost_occupancy",
    "plan_comm_on_batch",
    "predicted_loads",
    "remove_prefix",
    "replication_width",
    "select_reducers",
    "weighted_fair_allocation",
    "zero_reducers",
]
