"""Streaming SharesSkew: online micro-batch joins with drift-triggered
replanning (DESIGN.md §6; fused ingest hot path: §7).

  * ``sketch``  — decaying Count-Min + SpaceSaving heavy-hitter tracking
  * ``drift``   — cost-model staleness checks for the running plan
  * ``engine``  — stateful executor with carried reducer state; with
    ``StreamConfig(fused_ingest=True)`` the per-batch hot path runs
    through the ``kernels.ingest_fused`` Pallas pass
  * ``delta``   — sorted merge-join evaluation of the incremental-join
    terms for binary single-column joins (the fused path's delta engine)
"""
from .drift import DriftDecision, DriftMonitor, plan_comm_on_batch, predicted_loads
from .engine import BatchReport, StreamConfig, StreamingJoinEngine
from .sketch import DecayingCountMin, HHSnapshot, SpaceSaving, StreamHHTracker

__all__ = [
    "BatchReport",
    "DecayingCountMin",
    "DriftDecision",
    "DriftMonitor",
    "HHSnapshot",
    "SpaceSaving",
    "StreamConfig",
    "StreamingJoinEngine",
    "StreamHHTracker",
    "plan_comm_on_batch",
    "predicted_loads",
]
