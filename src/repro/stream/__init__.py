"""Streaming SharesSkew: online micro-batch joins with drift-triggered
replanning (DESIGN.md §6).

  * ``sketch``  — decaying Count-Min + SpaceSaving heavy-hitter tracking
  * ``drift``   — cost-model staleness checks for the running plan
  * ``engine``  — stateful executor with carried reducer state
"""
from .drift import DriftDecision, DriftMonitor, plan_comm_on_batch, predicted_loads
from .engine import BatchReport, StreamConfig, StreamingJoinEngine
from .sketch import DecayingCountMin, HHSnapshot, SpaceSaving, StreamHHTracker

__all__ = [
    "BatchReport",
    "DecayingCountMin",
    "DriftDecision",
    "DriftMonitor",
    "HHSnapshot",
    "SpaceSaving",
    "StreamConfig",
    "StreamingJoinEngine",
    "StreamHHTracker",
    "plan_comm_on_batch",
    "predicted_loads",
]
