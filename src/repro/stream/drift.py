"""Drift detection: when is the running plan stale enough to replan?
(DESIGN.md §6.)

A SharesSkew plan is optimal for the skew profile it was solved against.
Under drift two things go wrong, and each has a cheap per-batch check
against the live sketch — no replanning required to *decide*:

  * **Overload drift.**  A value that became heavy after planning is not
    pinned, so the ordinary residual hashes all its tuples to a single
    coordinate along its attribute: expected per-reducer load
    ``rate * x_attr / k`` (the ``k / x_attr`` reducers sharing that hash
    coordinate split the arrivals).  When any candidate's predicted load
    exceeds ``load_factor * q`` the plan has lost the paper's capacity
    guarantee.  Conversely a pinned value that faded keeps paying its
    residual's replication for nothing — wasted-replication drift.
  * **Communication drift.**  Evaluating the running plan's cost model
    (``CostExpression`` with the plan's integer shares) on the current
    batch's relevant sizes predicts this batch's shuffle exactly
    (``predicted_comm`` semantics, fresh sizes).  When that exceeds
    ``comm_factor`` x the per-batch volume the plan was installed against,
    the size profile has shifted.

Replanning is then one ``plan_with_hh`` call from the live sketch — the
expensive exact preliminary scan of the batch algorithm never runs.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import numpy as np

from repro.core.planner import SharesSkewPlan
from repro.core.residual import relevant_sizes
from repro.core.schema import JoinQuery

from .sketch import HHSnapshot


@dataclasses.dataclass(frozen=True)
class DriftDecision:
    replan: bool
    reason: str  # "" when not replanning
    predicted_comm: float  # running plan's comm on the current batch
    baseline_comm: float  # per-batch comm at install time
    worst_load: float  # worst predicted per-reducer load (tuples)
    worst_value: int | None  # the value predicting that load
    # machine-readable trigger (DESIGN.md §10): which check fired and the
    # observed-vs-threshold pair behind it.  "" / 0 / 0 when no check fired;
    # kept even when cooldown suppresses the replan, so telemetry can tell
    # "nothing drifted" apart from "drifted but still cooling down".
    trigger: str = ""  # "overload" | "comm" | "faded_pin" | ""
    observed: float = 0.0  # the quantity that crossed
    threshold: float = 0.0  # the value it crossed


def plan_comm_on_batch(
    plan: SharesSkewPlan, query: JoinQuery, data: Mapping[str, np.ndarray]
) -> float:
    """The shuffle volume the running plan will produce on ``data``:
    per residual, relevant size x integer-share replication (the
    ``mapreduce.executor.predicted_comm`` model with fresh sizes)."""
    total = 0.0
    for res in plan.residuals:
        sizes = relevant_sizes(query, data, res.combo, plan.hh_values)
        for rel in query.relations:
            total += sizes[rel.name] * res.int_replication(rel.attrs)
    return total


def predicted_loads(
    plan: SharesSkewPlan, snapshot: Mapping[str, HHSnapshot]
) -> list[tuple[int, str, float]]:
    """(value, attr, predicted per-reducer load) for each live HH candidate.

    Pinned values spread over their residual's whole grid (load rate/k);
    unpinned values hash to one coordinate of the residual that absorbs
    them, concentrating on k/x_attr reducers (load rate*x/k).
    """
    out: list[tuple[int, str, float]] = []
    ordinary = next((r for r in plan.residuals if not r.combo.pinned), None)
    for attr, snap in snapshot.items():
        pinned_vals = set(np.asarray(plan.hh_values.get(attr, ())).tolist())
        for v, rate in zip(snap.values.tolist(), snap.rates.tolist()):
            if v in pinned_vals:
                res = next(
                    (r for r in plan.residuals if r.combo.pinned.get(attr) == v),
                    None,
                )
                if res is not None:
                    out.append((v, attr, rate / max(1, res.num_reducers)))
            elif ordinary is not None:
                x = ordinary.solution.int_shares.get(attr, 1)
                k = max(1, ordinary.num_reducers)
                out.append((v, attr, rate * x / k))
    return out


class DriftMonitor:
    """Per-batch staleness check for the running plan."""

    def __init__(
        self,
        q: float,
        comm_factor: float = 1.5,
        load_factor: float = 3.0,
        fade_factor: float = 0.25,
        cooldown: int = 1,
    ):
        self.q = float(q)
        self.comm_factor = float(comm_factor)
        self.load_factor = float(load_factor)
        self.fade_factor = float(fade_factor)
        self.cooldown = int(cooldown)
        self._baseline_comm: float = 0.0
        self._since_replan: int = 0

    def install(
        self, plan: SharesSkewPlan, query: JoinQuery, data: Mapping[str, np.ndarray]
    ) -> None:
        """Record the per-batch volume the fresh plan predicts for the batch
        it was solved against — the reference point for comm drift."""
        self._baseline_comm = plan_comm_on_batch(plan, query, data)
        self._since_replan = 0

    def check(
        self,
        plan: SharesSkewPlan,
        query: JoinQuery,
        data: Mapping[str, np.ndarray],
        snapshot: Mapping[str, HHSnapshot],
        pinned_rates: Mapping[tuple[str, int], float] | None = None,
    ) -> DriftDecision:
        """``pinned_rates`` maps (attr, pinned value) -> live per-batch rate;
        when given, a pinned value whose rate faded below ``fade_factor * q``
        triggers wasted-replication drift (its residual keeps replicating
        the other relations for a value the stream has moved past).  The
        hysteresis gap between the pin threshold (~q) and ``fade_factor * q``
        prevents replan thrash for values hovering at the threshold."""
        comm = plan_comm_on_batch(plan, query, data)
        loads = predicted_loads(plan, snapshot)
        worst_value, _, worst_load = max(
            loads, key=lambda t: t[2], default=(None, "", 0.0)
        )
        self._since_replan += 1
        reason = ""
        trigger = ""
        observed = threshold = 0.0
        faded = [
            (a, v, r)
            for (a, v), r in (pinned_rates or {}).items()
            if r < self.fade_factor * self.q
        ]
        if worst_load > self.load_factor * self.q:
            trigger = "overload"
            observed, threshold = worst_load, self.load_factor * self.q
            reason = (
                f"overload: value {worst_value} predicts per-reducer load "
                f"{worst_load:.0f} > {self.load_factor:g}*q"
            )
        elif comm > self.comm_factor * self._baseline_comm and comm > 0:
            # a zero baseline (plan installed against an empty/near-empty
            # batch) must not disable the trigger: any real traffic on such
            # a degenerate plan is comm drift
            trigger = "comm"
            observed, threshold = comm, self.comm_factor * self._baseline_comm
            reason = (
                f"comm: predicted {comm:.0f} > {self.comm_factor:g}x "
                f"install baseline {self._baseline_comm:.0f}"
            )
        elif faded:
            a, v, r = faded[0]
            trigger = "faded_pin"
            observed, threshold = r, self.fade_factor * self.q
            reason = (
                f"faded pin: {a}={v} rate {r:.1f} < {self.fade_factor:g}*q; "
                "its residual replicates for a value the stream moved past"
            )
        replan = bool(reason) and self._since_replan > self.cooldown
        return DriftDecision(
            replan=replan,
            reason=reason if replan else "",
            predicted_comm=comm,
            baseline_comm=self._baseline_comm,
            worst_load=worst_load,
            worst_value=worst_value,
            trigger=trigger,
            observed=observed,
            threshold=threshold,
        )

    # ---- checkpoint (DESIGN.md §8) -----------------------------------------
    def state_dict(self) -> dict[str, np.ndarray]:
        """The two mutables that make drift decisions history-dependent:
        the install-time comm baseline and the cooldown counter.  Restoring
        them keeps post-restore replan decisions bit-identical to an
        uninterrupted run."""
        return {
            "scalars": np.array(
                [self._baseline_comm, float(self._since_replan)], np.float64
            )
        }

    def load_state_dict(self, state: dict[str, np.ndarray]) -> None:
        scalars = np.asarray(state["scalars"])
        self._baseline_comm = float(scalars[0])
        self._since_replan = int(scalars[1])
