"""Streaming SharesSkew: stateful micro-batch join executor (DESIGN.md §6).

Semantics: after ingesting batches 1..T the engine has produced exactly the
join of the concatenated input — same (count, checksum) fingerprint as
``mapreduce.run_join`` / ``oracle_join`` on the concatenation — while each
batch only ships its *new* tuples through the map phase (symmetric multiway
hash join: reducers keep what they received; history is never re-shuffled
except when a drift replan changes the reducer layout, which is a counted
state migration).

Per batch:
  1. admission control (``stream.admission``, optional): the backlog and
     the incoming batch are admitted up to a budget derived from the plan's
     ``q`` and the live sketch; the rest is deferred or shed with exact
     counters (``BatchReport.deferred/shed``);
  2. windowed retention (``stream.retention``, optional): batches that
     left the retained window are *retracted* — their contribution is
     subtracted from the window fingerprint via the same telescoping
     identity used for insertion, and their tuples leave carried state
     with a prefix shift (no shuffle);
  3. sketches observe the batch (``StreamHHTracker``, optionally via the
     Pallas ``cms_update`` kernel);
  4. the ``DriftMonitor`` re-evaluates the running plan's cost model
     against the live sketch; on drift, ``plan_with_hh`` installs a fresh
     plan and accumulated state is re-routed under it (migration);
  5. new tuples are routed with ``mapreduce.keys.map_phase`` — the same
     vectorized recursive_keys used by the batch executor and the
     distributed shuffle — and binned per reducer;
  6. the join delta is the n-term telescoping expansion
     Δ(R_1 ⋈ ... ⋈ R_n) = Σ_i  R_1^all ⋈ ... ⋈ R_{i-1}^all ⋈ ΔR_i
                                ⋈ R_{i+1}^old ⋈ ... ⋈ R_n^old
     evaluated with ``mapreduce.local_join.local_join_count_checksum`` over
     (old | new | merged) per-reducer bins, so counts and orderless
     checksums accumulate associatively mod 2^32.

With retention off (the default) the cumulative and window fingerprints
coincide and ``recompute_distributed()`` replays the full accumulated
input through ``mapreduce.shuffle.run_distributed`` under the current plan
— the cross-check that carried state lost nothing.  With retention on,
carried state is the retained suffix only: the cross-check becomes
``recompute_distributed(window=True)`` against the *window* fingerprint,
and asking for the full-stream cross-check raises (the input needed to
reproduce it no longer exists).

``save_checkpoint()`` / ``restore()`` serialize sketches, incumbent plan,
drift-monitor state, retained history, window clock, and admission backlog
through ``train.checkpoint`` (atomic step dirs + LATEST pointer), so a
preempted engine resumes mid-stream to the same cumulative (count,
checksum) — see DESIGN.md §8 for the format.

With ``StreamConfig(fused_ingest=True)`` (DESIGN.md §7) steps 3 and 5 run
as ONE speculative pass per relation through ``kernels.ingest_fused``
(destinations + sketch increment + pack plan), and step 6's terms use the
sorted merge join of ``stream.delta`` for binary single-column queries.
Every fused-path result is bit-identical to this baseline, which stays in
the tree as the correctness oracle.
"""
from __future__ import annotations

import dataclasses
import pickle
import time
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core.planner import SharesSkewPlan, plan_with_hh, repair_plan
from repro.core.schema import JoinQuery
from repro.mapreduce.keys import map_phase, static_route_table
from repro.mapreduce.local_join import (
    LocalJoinSpec,
    local_join_count_checksum,
    local_join_count_checksum_jit,
)
from repro.mapreduce.straggler import FailureDetector
from repro.obs import NULL_OBS, Observability, ObsPolicy, cms_window_error, hh_hit_counts

from .admission import AdmissionController, AdmissionPolicy
from .delta import SortedDeltaIndex
from .drift import DriftDecision, DriftMonitor
from .recovery import (
    HostTracker,
    RecoveryExhaustedError,
    RecoveryPolicy,
    RecoveryReport,
    record_recovery,
)
from .retention import (
    RetentionPolicy,
    carried_tuples,
    lost_occupancy,
    remove_prefix,
    select_reducers,
    zero_reducers,
)
from .sketch import StreamHHTracker

_MASK32 = 0xFFFFFFFF

CHECKPOINT_FORMAT = 1  # bump on any layout change; restore() validates it


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Tuning knobs for the streaming engine."""

    q: float  # per-reducer capacity the plans are solved for
    hh_threshold: float | None = None  # per-batch HH rate threshold (default q)
    decay: float = 0.5  # sketch EMA decay per batch
    sketch_width: int = 2048
    sketch_depth: int = 4
    ss_capacity: int = 64
    max_hh_per_attr: int = 8
    comm_factor: float = 1.5  # comm drift trigger
    load_factor: float = 3.0  # overload drift trigger
    fade_factor: float = 0.25  # wasted-replication (faded pin) drift trigger
    cooldown: int = 1  # batches after a replan during which drift is ignored
    use_device_sketch: bool = False  # route CMS updates through the Pallas kernel
    sketch_seed: int = 0
    # Fused ingest (DESIGN.md §7): one Pallas pass per relation computes
    # map-phase destinations, the Count-Min increment, and the pack plan
    # (per-reducer counts + in-destination ranks).  Bit-identical to the
    # baseline path, which remains the correctness oracle.
    fused_ingest: bool = False
    fused_block: int = 256  # tuple block per grid step / DMA slot
    fused_double_buffer: bool = True  # explicit DMA double buffering
    # Route the fused pass through the dense (dynamic-operand) route
    # encoding: only padded shapes are jit-static, so a drift replan that
    # keeps the same (W_pad, k_pad) bucket reuses the compiled executable
    # instead of paying a multi-second recompile on the replan batch.
    # Bit-identical to the static-route variant.
    fused_dynamic_routes: bool = True
    # Bounded state (DESIGN.md §8): both default to off, reproducing the
    # unbounded §6 baseline bit-for-bit.
    retention: RetentionPolicy = RetentionPolicy()
    admission: AdmissionPolicy = AdmissionPolicy()
    # Reducer-loss recovery (DESIGN.md §5): off by default; with
    # ``RecoveryPolicy(n_hosts=H)`` reducers multiplex over H simulated
    # hosts, host loss is detected by heartbeat deadline and recovered by
    # lineage replay / plan repair at batch boundaries.
    recovery: RecoveryPolicy = RecoveryPolicy()
    # Observability (DESIGN.md §10): spans, metrics, per-reducer load
    # telemetry.  All off by default — disabled hooks are free.
    obs: ObsPolicy = ObsPolicy()


@dataclasses.dataclass(frozen=True)
class BatchReport:
    """Telemetry for one ingested micro-batch."""

    batch: int  # 0-based batch index
    plan_epoch: int  # increments at every replan
    replanned: bool
    drift_reason: str  # why the replan fired ("" otherwise)
    delta_count: int  # join results contributed by this batch
    total_count: int  # cumulative join count
    total_checksum: int  # cumulative orderless checksum (mod 2^32)
    comm_tuples: dict[str, int]  # new tuples shipped this batch, per relation
    cumulative_comm: int  # all new-tuple shipments so far (excl. migration)
    migrated_tuples: int  # state re-routed by this batch's replan (0 if none)
    max_load: int  # worst per-reducer arrivals this plan epoch
    hh_values: dict[str, list[int]]  # live plan's pinned HH set
    # bounded-state telemetry (DESIGN.md §8); zeros when retention and
    # admission are off
    deferred: dict[str, int]  # rows queued in the backlog after this batch
    shed: dict[str, int]  # rows dropped by admission this batch
    expired_batches: int  # batches retired from the window this ingest
    retracted_count: int  # join results retracted from the window fingerprint
    window_count: int  # fingerprint of the retained window (== total_* when
    window_checksum: int  # retention is off)
    carried_tuples: int  # retained emissions across all reducers/relations
    max_carried: int  # worst per-reducer retained occupancy
    # drift-trigger telemetry (DESIGN.md §10): which drift check fired the
    # replan and the observed-vs-threshold pair behind it.  "initial" for
    # the first plan; "" when this batch did not replan.
    drift_trigger: str = ""
    drift_observed: float = 0.0
    drift_threshold: float = 0.0
    # observability payload (metrics snapshot + skew snapshot) — excluded
    # from equality: histogram sums carry wall time, and the baseline-vs-
    # fused parity assertions compare everything else bit-for-bit
    obs: dict | None = dataclasses.field(default=None, compare=False, repr=False)

    @property
    def total_comm(self) -> int:
        return int(sum(self.comm_tuples.values()))


def _group_np(
    dest: np.ndarray, rows: np.ndarray, k: int, cap: int
) -> tuple[np.ndarray, np.ndarray]:
    """Exact host-side group_by_reducer (no capacity drops; cap must cover
    the true max occupancy).  Returns (bins [k, cap, arity], valid [k, cap])."""
    arity = rows.shape[1]
    bins = np.zeros((k, cap, arity), dtype=np.int32)
    valid = np.zeros((k, cap), dtype=bool)
    if dest.size:
        order = np.argsort(dest, kind="stable")
        ds, rs = dest[order], rows[order]
        first = np.searchsorted(ds, ds, side="left")
        rank = (np.arange(ds.size) - first).astype(np.int64)
        bins[ds, rank] = rs
        valid[ds, rank] = True
    return bins, valid


def _pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class _Routed:
    """One relation's routed batch: the valid emissions after map_phase.

    ``rank`` (fused path only) is each emission's arrival index within its
    destination — the kernel's pack plan, which turns every downstream
    grouping into a precomputed-index scatter.  ``counts`` is the
    per-reducer arrival histogram (= ``np.bincount(dest, minlength=k)``).
    """

    dest: np.ndarray  # [E] int32 reducer ids (valid only)
    rows: np.ndarray  # [E, arity] int32
    rank: np.ndarray | None  # [E] in-destination ranks, None on baseline
    counts: np.ndarray  # [k] int64 arrivals per reducer


class StreamingJoinEngine:
    """Online SharesSkew join over an unbounded micro-batch sequence."""

    def __init__(
        self,
        query: JoinQuery,
        config: StreamConfig,
        log_fn: Callable[[str], None] | None = None,
        clock: Callable[[], float] | None = None,
        obs: Observability | None = None,
    ):
        self.query = query
        self.config = config
        self.spec = LocalJoinSpec.from_query(query)
        # observability facade: an injected one (MultiQueryEngine hands each
        # tenant a labeled view of SHARED tracer+registry) wins; otherwise
        # built from config.obs; NULL_OBS keeps every hook free when off
        arities = {r.name: r.arity for r in query.relations}
        if obs is not None:
            self.obs = obs
        elif config.obs.any:
            self.obs = Observability(config.obs, arities=arities)
        else:
            self.obs = NULL_OBS
        self.tracker = StreamHHTracker(
            query,
            width=config.sketch_width,
            depth=config.sketch_depth,
            capacity=config.ss_capacity,
            decay=config.decay,
            seed=config.sketch_seed,
            use_device_sketch=config.use_device_sketch,
        )
        self.monitor = DriftMonitor(
            config.q,
            comm_factor=config.comm_factor,
            load_factor=config.load_factor,
            fade_factor=config.fade_factor,
            cooldown=config.cooldown,
        )
        self.plan: SharesSkewPlan | None = None
        self.plan_epoch = -1
        self._log = log_fn or (lambda _msg: None)
        self._clock = clock or time.monotonic

        # retained raw history (per relation, one entry per retained batch)
        # for replan migration; with retention on, expired batches are
        # dropped so migration re-routes the retained suffix only
        self._history: dict[str, list[np.ndarray]] = {
            r.name: [] for r in query.relations
        }
        # window bookkeeping, aligned with _history entries
        self._retained_ids: list[int] = []  # batch indices still retained
        self._batch_ts: list[float] = []  # ingest clock per retained batch
        # per-batch routed emissions under the CURRENT plan — kept only
        # when retention is on (retraction needs them); rebuilt at replans
        self._routed_log: dict[str, list[_Routed]] = {
            r.name: [] for r in query.relations
        }
        # carried reducer state under the CURRENT plan, kept binned:
        # name -> (bins [k, cap, arity], valid [k, cap], occup [k]).
        # Appending a batch is a host-side scatter at rank offsets — never a
        # re-sort of history, and no per-shape device op churn; only a
        # replan rebuilds from scratch.
        self._state: dict[str, tuple[np.ndarray, np.ndarray, np.ndarray]] = {}
        self._loads: np.ndarray = np.zeros(0, dtype=np.int64)

        self.total_count = 0
        self.total_checksum = 0
        # sketch passes THIS engine computed itself (multi-tenant sharing:
        # an engine absorbing shared increments never bumps this — the
        # tenancy tests assert the shared pass ran once per relation batch)
        self.sketch_ingest_calls = 0
        # recovery-domain label: "" single-tenant; MultiQueryEngine sets it
        # so tenant-scoped host faults fire only in the victim's engine
        self.tenant = ""
        self.window_count = 0  # fingerprint of the retained window
        self.window_checksum = 0
        self.cumulative_comm = 0
        self.total_migrated = 0
        self.expired_batches = 0  # batches retired from the window so far
        self.total_retracted = 0  # results retracted from the window so far
        self.reports: list[BatchReport] = []

        self._controller: AdmissionController | None = (
            AdmissionController(config.admission, query, config.q)
            if config.admission.enabled
            else None
        )

        # reducer-loss recovery (DESIGN.md §5): host placement, heartbeat
        # detector clocked in batch indices, and the per-event reports
        self._hosts: HostTracker | None = (
            HostTracker(config.recovery) if config.recovery.enabled else None
        )
        self._detector: FailureDetector | None = (
            FailureDetector(config.recovery.deadline_batches)
            if config.recovery.enabled
            else None
        )
        self._fault_injector = None  # armed via arm_faults()
        self._pending_host_events: list = []
        self._exhausted = False
        self._slots_per_host = 1
        self.recoveries: list[RecoveryReport] = []
        self.total_replayed = 0

        # fused-ingest bookkeeping: columns the kernel must sketch per
        # relation (tracker attr order), and a loud counter so callers can
        # verify the fused path actually ran (no silent fallback exists,
        # but benchmarks assert on this to keep it that way)
        self._sketch_cols: dict[str, tuple[tuple[str, int], ...]] = {
            rel.name: tuple(
                (a, rel.index_of(a))
                for a in self.tracker.attrs
                if a in rel.attrs
            )
            for rel in query.relations
        }
        self.fused_batches = 0
        # dense route-encoding cache (fused_dynamic_routes): rebuilt per
        # plan epoch; the padded width is a per-relation high-water mark so
        # an oscillating replan width cannot thrash the jit cache
        self._dense_enc: dict[str, tuple] = {}
        self._dense_wp: dict[str, int] = {}
        # merge-join delta index (DESIGN.md §7): exact sorted-key evaluation
        # of the telescoping terms for binary single-column joins, replacing
        # the dense einsum whose cost is padded to the hottest reducer bin.
        # Bit-identical; the einsum stays the oracle (and the n-way path).
        self._delta_index: SortedDeltaIndex | None = (
            SortedDeltaIndex(self.spec)
            if config.fused_ingest and SortedDeltaIndex.eligible(self.spec)
            else None
        )

    # ---- internals ---------------------------------------------------------
    def _validate_batch(
        self, batch: dict[str, np.ndarray]
    ) -> dict[str, np.ndarray]:
        """Schema-validate one offered batch BEFORE any state mutation.

        The containment contract for multi-tenant quarantine (DESIGN.md
        §9): a poison-pill batch (missing relation, wrong arity, NaN,
        values outside the int32 routing domain) raises ``ValueError``
        here, with the engine untouched — no backlog mutated, no window
        expired, no sketch decayed — so a circuit-breaker reopen can
        safely retry the next batch on the same engine."""
        out = {}
        for r in self.query.relations:
            if r.name not in batch:
                raise ValueError(
                    f"poisoned batch: missing relation {r.name!r}"
                )
            rows = np.asarray(batch[r.name])
            if rows.dtype == object or not (
                np.issubdtype(rows.dtype, np.integer)
                or np.issubdtype(rows.dtype, np.floating)
            ):
                raise ValueError(
                    f"poisoned batch: relation {r.name!r} has non-numeric "
                    f"dtype {rows.dtype}"
                )
            if rows.ndim == 2 and rows.shape[1] != r.arity:
                raise ValueError(
                    f"poisoned batch: relation {r.name!r} rows have "
                    f"{rows.shape[1]} columns, schema arity is {r.arity}"
                )
            if rows.ndim > 2 or (rows.ndim < 2 and rows.size % r.arity):
                raise ValueError(
                    f"poisoned batch: relation {r.name!r} shape "
                    f"{rows.shape} does not pack into arity {r.arity}"
                )
            if np.issubdtype(rows.dtype, np.floating):
                if rows.size and not np.isfinite(rows).all():
                    raise ValueError(
                        f"poisoned batch: relation {r.name!r} contains "
                        "non-finite values"
                    )
            if rows.size:
                lo, hi = rows.min(), rows.max()
                if hi >= 2**31 or lo < -(2**31):
                    raise ValueError(
                        f"poisoned batch: relation {r.name!r} values "
                        f"[{lo}, {hi}] leave the int32 routing domain"
                    )
            out[r.name] = rows.reshape(-1, r.arity)
        return out

    def _threshold(self) -> float:
        t = self.config.hh_threshold
        return float(self.config.q if t is None else t)

    def _route(self, rel, rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """map_phase one relation; returns flat (dest, rows) of valid
        emissions (the per-tuple replication already expanded)."""
        arity = rows.shape[1]
        if rows.shape[0] == 0:
            return np.empty(0, np.int32), np.empty((0, arity), np.int32)
        rows32 = jnp.asarray(rows.astype(np.int32))
        dest = np.asarray(map_phase(self.plan, rel, rows32))  # [N, W]
        n, w = dest.shape
        flat_dest = dest.reshape(-1)
        flat_rows = np.broadcast_to(
            rows.astype(np.int32)[:, None, :], (n, w, arity)
        ).reshape(-1, arity)
        ok = flat_dest >= 0
        return flat_dest[ok].astype(np.int32), flat_rows[ok]

    def _dense_routes(self, rel, routes: tuple):
        """(enc, real_width, k_pad) for the dense fused pass, cached per
        plan epoch.  The padded route width only ever grows (per-relation
        high-water mark), so successive replans whose real width fits the
        same power-of-two bucket hit the identical compiled executable."""
        from repro.kernels.ingest_fused import dense_route_encoding, route_width

        cached = self._dense_enc.get(rel.name)
        if cached is not None and cached[0] == self.plan_epoch:
            return cached[1], cached[2], cached[3]
        w = route_width(routes)
        wp = max(_pow2(max(w, 1)), self._dense_wp.get(rel.name, 1))
        self._dense_wp[rel.name] = wp
        k_pad = max(-(-self.plan.total_reducers // 128) * 128, 128)
        enc = dense_route_encoding(
            routes, rel.arity, wp,
            max_values=max(1, self.config.max_hh_per_attr),
        )
        self._dense_enc[rel.name] = (self.plan_epoch, enc, w, k_pad)
        return enc, w, k_pad

    def _fused_pass(
        self, rel, rows: np.ndarray, with_route: bool, with_sketch: bool
    ) -> tuple[_Routed | None, dict[str, np.ndarray] | None]:
        """One fused-kernel pass over ``rows`` (DESIGN.md §7).

        Returns (routed emissions under the CURRENT plan if ``with_route``,
        per-attr Count-Min table increments if ``with_sketch``)."""
        from repro.kernels import fused_ingest, fused_ingest_dense

        arity = rows.shape[1]
        cols = self._sketch_cols[rel.name] if with_sketch else ()
        seeds = self.tracker.seeds
        width = self.config.sketch_width
        k = self.plan.total_reducers if with_route else 1
        routes = static_route_table(self.plan, rel) if with_route else ()

        empty_routed = _Routed(
            np.empty(0, np.int32),
            np.empty((0, arity), np.int32),
            np.empty(0, np.int32),
            np.zeros(k, np.int64),
        )
        zero_deltas = {
            a: np.zeros((len(seeds), width), np.float64) for a, _ in cols
        }
        if rows.shape[0] == 0 or (not routes and not cols):
            return (empty_routed if with_route else None), (
                zero_deltas if with_sketch else None
            )

        if routes and self.config.fused_dynamic_routes:
            enc, w_real, k_pad = self._dense_routes(rel, routes)
            dest, rank, counts, cms = fused_ingest_dense(
                jnp.asarray(rows.astype(np.int32)),
                enc,
                sketch_cols=tuple(c for _, c in cols),
                seeds=seeds,
                width=width,
                k_pad=k_pad,
                block=self.config.fused_block,
                double_buffer=self.config.fused_double_buffer,
            )
            # the dense kernel returns padded (N_pad, W_pad, k_pad) shapes
            # so the executable survives replans; slice to real sizes here
            n = rows.shape[0]
            dest = np.asarray(dest)[:n, :w_real]
            rank = np.asarray(rank)[:n, :w_real]
            counts = np.asarray(counts)[:k]
        else:
            dest, rank, counts, cms = fused_ingest(
                jnp.asarray(rows.astype(np.int32)),
                routes=routes,
                sketch_cols=tuple(c for _, c in cols),
                seeds=seeds,
                width=width,
                num_reducers=k,
                block=self.config.fused_block,
                double_buffer=self.config.fused_double_buffer,
            )
        routed = None
        if with_route:
            dest, rank = np.asarray(dest), np.asarray(rank)
            n, w = dest.shape
            flat_dest = dest.reshape(-1)
            flat_rank = rank.reshape(-1)
            flat_rows = np.broadcast_to(
                rows.astype(np.int32)[:, None, :], (n, w, arity)
            ).reshape(-1, arity)
            ok = flat_dest >= 0
            routed = _Routed(
                flat_dest[ok].astype(np.int32),
                flat_rows[ok],
                flat_rank[ok],
                np.asarray(counts).astype(np.int64),
            )
        deltas = None
        if with_sketch:
            cms_np = np.asarray(cms) if cms is not None else None
            deltas = {
                a: cms_np[i].astype(np.float64)
                for i, (a, _) in enumerate(cols)
            }
        return routed, deltas

    def _route_any(self, rel, rows: np.ndarray) -> _Routed:
        """Route one relation under the current plan — fused kernel or the
        baseline ``map_phase`` path, per config."""
        if self.config.fused_ingest:
            # sketch mode stays ON even though the increments are discarded
            # here: route-only calls (replan re-routes, migrations) then hit
            # the same compiled kernel variant as the speculative per-batch
            # pass, so the batch after a replan pays no recompile
            routed, _ = self._fused_pass(
                rel, rows, True, bool(self._sketch_cols[rel.name])
            )
            return routed
        dest, emitted = self._route(rel, rows)
        counts = np.bincount(
            dest, minlength=self.plan.total_reducers
        ).astype(np.int64)
        return _Routed(dest, emitted, None, counts)

    def _empty_state(
        self, arity: int
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        k = self.plan.total_reducers
        return (
            np.zeros((k, 1, arity), np.int32),
            np.zeros((k, 1), bool),
            np.zeros(k, np.int64),
        )

    def _scatter_into(
        self,
        state: tuple[np.ndarray, np.ndarray, np.ndarray],
        dest: np.ndarray,
        rows: np.ndarray,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Append emissions to a binned state: slot = rank-in-group + current
        occupancy.  Grows cap (pow2) when a reducer's bin fills."""
        bins, valid, occup = state
        k = bins.shape[0]
        if dest.size == 0:
            return state
        counts = np.bincount(dest, minlength=k)
        new_occup = occup + counts
        cap = bins.shape[1]
        cap_needed = int(new_occup.max())
        if cap_needed > cap:
            new_cap = _pow2(cap_needed)
            bins = np.pad(bins, ((0, 0), (0, new_cap - cap), (0, 0)))
            valid = np.pad(valid, ((0, 0), (0, new_cap - cap)))
        else:
            bins, valid = bins.copy(), valid.copy()
        order = np.argsort(dest, kind="stable")
        ds, rs = dest[order], rows[order]
        first = np.searchsorted(ds, ds, side="left")
        rank = np.arange(ds.size) - first + occup[ds]
        bins[ds, rank] = rs
        valid[ds, rank] = True
        return bins, valid, new_occup

    def _scatter_any(
        self,
        state: tuple[np.ndarray, np.ndarray, np.ndarray],
        routed: _Routed,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Append a routed batch to a binned state.  With a fused-kernel
        pack plan the slot is ``occupancy + rank`` directly (no sort); the
        result is bit-identical to ``_scatter_into``."""
        if routed.rank is None:
            return self._scatter_into(state, routed.dest, routed.rows)
        bins, valid, occup = state
        if routed.dest.size == 0:
            return state
        new_occup = occup + routed.counts
        cap = bins.shape[1]
        cap_needed = int(new_occup.max())
        if cap_needed > cap:
            new_cap = _pow2(cap_needed)
            bins = np.pad(bins, ((0, 0), (0, new_cap - cap), (0, 0)))
            valid = np.pad(valid, ((0, 0), (0, new_cap - cap)))
        else:
            bins, valid = bins.copy(), valid.copy()
        slots = routed.rank + occup[routed.dest]
        bins[routed.dest, slots] = routed.rows
        valid[routed.dest, slots] = True
        return bins, valid, new_occup

    def _rebuild_routed_state(self) -> int:
        """Re-route every retained batch under ``self.plan`` from scratch:
        binned state, per-reducer loads, the per-batch routed log (when
        retention needs it), and the sorted delta index.  Batch-sequential
        scatters reproduce the concatenated route bit-for-bit (map_phase is
        per-row deterministic and appends preserve arrival order).  Returns
        the number of emissions routed — the migration count at replans.
        This is also where retention's deferred *compaction* lands: bins
        are rebuilt at tight capacity over the retained suffix only, so
        expiry never needs its own shuffle or re-route."""
        keep_log = self.config.retention.enabled
        self._loads = np.zeros(self.plan.total_reducers, dtype=np.int64)
        skew = self.obs.skew
        if skew is not None:  # mirror of the _loads reset: new reducer space
            skew.install(self.plan.total_reducers)
        self._routed_log = {r.name: [] for r in self.query.relations}
        if self._delta_index is not None:
            for nm in self.spec.rel_names:
                self._delta_index.clear(nm)
        for rel in self.query.relations:
            self._state[rel.name] = self._empty_state(rel.arity)
        total = 0
        first_route = True
        for i, bid in enumerate(self._retained_ids):
            for rel in self.query.relations:
                nm = rel.name
                if first_route:
                    # the first kernel invocation under the new plan pays
                    # any jit (re)compile — clock it apart from migration
                    with self.obs.span("replan.compile", args={"rel": nm}):
                        routed = self._route_any(rel, self._history[nm][i])
                    first_route = False
                else:
                    routed = self._route_any(rel, self._history[nm][i])
                self._state[nm] = self._scatter_any(self._state[nm], routed)
                if keep_log:
                    self._routed_log[nm].append(routed)
                if self._delta_index is not None:
                    self._delta_index.append(nm, routed.dest, routed.rows, bid)
                self._loads += routed.counts
                if skew is not None:
                    skew.record(nm, routed.counts)
                total += int(routed.dest.size)
        return total

    def _install(self, plan: SharesSkewPlan, batch: dict[str, np.ndarray]) -> int:
        """Switch to ``plan``; re-route retained history under it.
        Returns the number of migrated emissions."""
        self.plan = plan
        self.plan_epoch += 1
        self.monitor.install(plan, self.query, batch)
        with self.obs.span(
            "replan.migrate", args={"epoch": self.plan_epoch}
        ):
            migrated = self._rebuild_routed_state()
        self.total_migrated += migrated
        if self._hosts is not None:
            self._hosts.assign(plan.total_reducers)
            self._slots_per_host = max(
                1,
                -(-plan.total_reducers // max(1, len(self._hosts.alive))),
            )
        return migrated

    # ---- retention (DESIGN.md §8) ------------------------------------------
    def _retract_sorted(
        self, bid: int, expired: dict[str, _Routed]
    ) -> tuple[int, int]:
        """Retraction terms via ``SortedDeltaIndex``.  Term i of
        join(A) − join(S) is A_1..A_{i-1} ⋈ E_i ⋈ S_{i+1}..S_n, so probing
        runs in *reverse* relation order: E_i probes the other relation's
        index after relations > i already expired (mirror of insertion)."""
        idx = self._delta_index
        names = self.spec.rel_names
        d_count, d_checksum = 0, 0
        for i in reversed(range(len(names))):
            nm = names[i]
            e = expired[nm]
            idx.expire(nm, bid)  # E_i leaves its own index first (j == i)
            if e.dest.size:
                cnt, chk = idx.probe(names[1 - i], nm, e.dest, e.rows)
                d_count += cnt
                d_checksum = (d_checksum + chk) & _MASK32
        return d_count, d_checksum

    def _retract_einsum(
        self,
        expired: dict[str, _Routed],
        survivors: dict[str, tuple[np.ndarray, np.ndarray, np.ndarray]],
    ) -> tuple[int, int]:
        """Retraction terms via the einsum path: j<i → current state (A,
        expiring batch still resident), j==i → the expiring emissions E,
        j>i → survivors S.  Exact mirror of the insertion telescoping."""
        k = self.plan.total_reducers
        variants: dict[str, dict[str, tuple[jnp.ndarray, jnp.ndarray]]] = {}
        for rel in self.query.relations:
            nm = rel.name
            e = expired[nm]
            ecap = _pow2(max(int(e.counts.max()) if e.dest.size else 0, 1))
            ebins, evalid = _group_np(e.dest, e.rows, k, ecap)
            abins, avalid, _ = self._state[nm]
            sbins, svalid, _ = survivors[nm]
            variants[nm] = {
                "all": (jnp.asarray(abins), jnp.asarray(avalid)),
                "exp": (jnp.asarray(ebins), jnp.asarray(evalid)),
                "old": (jnp.asarray(sbins), jnp.asarray(svalid)),
            }
        join_fn = (
            local_join_count_checksum_jit
            if self.config.fused_ingest
            else local_join_count_checksum
        )
        names = [r.name for r in self.query.relations]
        d_count, d_checksum = 0, 0
        for i, nm_i in enumerate(names):
            if expired[nm_i].dest.size == 0:
                continue  # E_i empty -> term contributes nothing
            bins, valids = {}, {}
            for j, nm_j in enumerate(names):
                key = "all" if j < i else ("exp" if j == i else "old")
                bins[nm_j], valids[nm_j] = variants[nm_j][key]
            cnt, chk = join_fn(self.spec, bins, valids)
            d_count += int(cnt)
            d_checksum = (d_checksum + int(np.uint32(chk))) & _MASK32
        return d_count, d_checksum

    def _retract_oldest(self) -> int:
        """Expire the oldest retained batch: subtract its window-join
        contribution (exact, mod 2^32) and shift its tuples out of carried
        state.  Pure host-side compute on already-routed state — expiry
        never re-shuffles (capacity compaction rides the replan rebuild).
        Returns the number of retracted join results."""
        bid = self._retained_ids.pop(0)
        self._batch_ts.pop(0)
        expired = {nm: self._routed_log[nm].pop(0) for nm in self._routed_log}
        for rel in self.query.relations:
            self._history[rel.name].pop(0)
        survivors = {
            nm: remove_prefix(self._state[nm], expired[nm].counts)
            for nm in self._state
        }
        if self._delta_index is not None:
            cnt, chk = self._retract_sorted(bid, expired)
        else:
            cnt, chk = self._retract_einsum(expired, survivors)
        self._state.update(survivors)
        self.window_count -= cnt
        self.window_checksum = (self.window_checksum - chk) & _MASK32
        self.expired_batches += 1
        self.total_retracted += cnt
        return cnt

    def _expire_due(self, now: float) -> tuple[int, int]:
        """Retire every retained batch outside the window/TTL before the
        next ingest.  Returns (batches expired, results retracted)."""
        policy = self.config.retention
        if not policy.enabled or not self._retained_ids:
            return 0, 0
        drop = policy.expired_prefix(
            self._retained_ids, self._batch_ts, len(self.reports), now
        )
        retracted = 0
        for _ in range(drop):
            retracted += self._retract_oldest()
        if drop:
            self._log(
                f"[stream] expired {drop} batch(es) from the window; "
                f"retracted {retracted} results"
            )
        return drop, retracted

    # ---- admission (DESIGN.md §8) ------------------------------------------
    def _concentration(self) -> float:
        """Predicted worst per-reducer load ÷ q for the live skew profile —
        the admission budget's skew-tightening factor."""
        from .drift import predicted_loads

        if self.plan is None:
            return 1.0
        snapshot = self.tracker.snapshot(
            self._threshold(), self.config.max_hh_per_attr
        )
        loads = predicted_loads(self.plan, snapshot)
        worst = max((load for _, _, load in loads), default=0.0)
        return max(1.0, worst / max(self.config.q, 1e-9))

    # ---- reducer-loss recovery (DESIGN.md §5) ------------------------------
    def arm_faults(self, injector) -> None:
        """Attach a ``repro.testing.faults.FaultInjector`` whose host faults
        (``host_loss`` / ``partition``) fire at absolute batch indices at
        the ingest boundary.  Indices are absolute (``len(reports)``), so a
        restored engine resumes past already-fired faults — they never
        re-fire across a checkpoint boundary."""
        self._fault_injector = injector

    def _last_batch(self) -> dict[str, np.ndarray]:
        """Most recent retained batch (drift-monitor baseline for a repair
        install); empty arrays when nothing is retained."""
        return {
            r.name: (
                self._history[r.name][-1]
                if self._history[r.name]
                else np.zeros((0, r.arity), dtype=np.int64)
            )
            for r in self.query.relations
        }

    def _lineage(self, rel, i: int) -> _Routed:
        """Batch ``i``'s routed emissions for one relation: the retained
        routed log when retention keeps it (true lineage), else a
        deterministic re-route of the retained raw batch — ``map_phase``
        is per-row deterministic, so both reproduce the original emission
        order exactly."""
        if self.config.retention.enabled:
            return self._routed_log[rel.name][i]
        return self._route_any(rel, self._history[rel.name][i])

    def _state_join_fingerprint(self) -> tuple[int, int]:
        """(count, checksum) of the join evaluated over the carried binned
        state — the einsum oracle the window fingerprint must match."""
        bins = {nm: jnp.asarray(b) for nm, (b, _, _) in self._state.items()}
        valids = {nm: jnp.asarray(v) for nm, (_, v, _) in self._state.items()}
        cnt, chk = local_join_count_checksum(self.spec, bins, valids)
        return int(cnt), int(np.uint32(chk)) & _MASK32

    def _resolve_host_events(self, lost_hosts, recovered: bool) -> None:
        from repro.testing.faults import FaultInjector

        for ev in self._pending_host_events:
            if not ev.resolved and (
                ev.spec.host_id in lost_hosts or not recovered
            ):
                FaultInjector.mark_host_event(ev, recovered)

    def _exhaust(self, lost_hosts, msg: str) -> None:
        """Loss beyond the survivable grid: flag the engine dead, resolve
        the injector events as explicitly reported, and raise."""
        self._exhausted = True
        self._resolve_host_events(lost_hosts, recovered=False)
        raise RecoveryExhaustedError(msg)

    def _replay_lost(self, lost_ids: np.ndarray) -> int:
        """Lineage replay (DESIGN.md §5 stage 3): zero the lost reducers'
        bins, then re-scatter ONLY their emissions from each retained
        batch, in batch order — reproducing the dead bins bit-for-bit
        (appends land at occupancy offsets, so a batch's emissions refill
        as the same prefix they originally occupied).  Returns the number
        of replayed emissions."""
        for nm in self._state:
            self._state[nm] = zero_reducers(self._state[nm], lost_ids)
        if self._delta_index is not None:
            for nm in self.spec.rel_names:
                self._delta_index.drop_reducers(nm, lost_ids)
        replayed = 0
        for i, rbid in enumerate(self._retained_ids):
            for rel in self.query.relations:
                nm = rel.name
                routed = self._lineage(rel, i)
                mask = select_reducers(routed.dest, lost_ids)
                if not mask.any():
                    continue
                sub = _Routed(
                    routed.dest[mask],
                    routed.rows[mask],
                    None if routed.rank is None else routed.rank[mask],
                    np.bincount(
                        routed.dest[mask], minlength=self.plan.total_reducers
                    ).astype(np.int64),
                )
                self._state[nm] = self._scatter_any(self._state[nm], sub)
                if self._delta_index is not None:
                    self._delta_index.append(nm, sub.dest, sub.rows, rbid)
                replayed += int(sub.dest.size)
        return replayed

    def _recover(self, lost_hosts: list[int], bid: int) -> RecoveryReport:
        """Detection has declared ``lost_hosts`` dead: repair placement (or
        the plan), reconstruct the lost reducers' carried state, verify
        the window fingerprint, and report.  Raises
        ``RecoveryExhaustedError`` when the survivors cannot host a
        correct plan — explicit, never a silent wrong answer."""
        policy = self.config.recovery
        hosts = self._hosts
        self.obs.instant(
            "recovery.detect",
            cat="recovery",
            args={"hosts": sorted(lost_hosts), "batch": bid},
        )
        lost_ids = hosts.reducers_on(lost_hosts)
        hosts.declare_lost(lost_hosts)
        for h in lost_hosts:
            self._detector.deregister(h)
        survivors = len(hosts.alive)
        if survivors < policy.min_hosts:
            self._exhaust(
                lost_hosts,
                f"recovery exhausted at batch {bid}: {survivors} surviving "
                f"host(s) < min_hosts={policy.min_hosts} "
                f"(lost {sorted(lost_hosts)})",
            )
        reducers_before = self.plan.total_reducers if self.plan else 0
        lost_share = lost_occupancy(self._state, lost_ids)
        degrade = (
            self.plan is not None
            and survivors / hosts.provisioned < policy.degrade_below
        )
        replayed = migrated = 0
        if self.plan is None or lost_ids.size == 0:
            mode = "replay"  # nothing carried yet; placement repair only
            hosts.reassign(lost_ids)
        elif not degrade:
            mode = "replay"
            hosts.reassign(lost_ids)
            with self.obs.span(
                "recovery.replay",
                cat="recovery",
                args={"lost_reducers": int(lost_ids.size)},
            ):
                replayed = self._replay_lost(lost_ids)
        else:
            mode = "degrade"
            from repro.train.elastic import plan_mesh_shape

            mesh = plan_mesh_shape(
                survivors, 1, chips_per_pod=policy.hosts_per_pod
            )
            k_target = mesh.chips_used * self._slots_per_host
            try:
                repaired = repair_plan(self.plan, k_target)
            except ValueError as e:
                self._exhaust(
                    lost_hosts, f"recovery exhausted at batch {bid}: {e}"
                )
            # full rebuild under the repaired plan reconstructs every
            # reducer's state (lost bins included) and re-places reducers
            # over the survivors; admission tightens to surviving capacity
            with self.obs.span(
                "recovery.repair",
                cat="recovery",
                args={"k_target": k_target, "survivors": survivors},
            ):
                migrated = self._install(repaired, self._last_batch())
            if self._controller is not None:
                self._controller.set_capacity(survivors / hosts.provisioned)
        verified = True
        if policy.verify and self.plan is not None:
            with self.obs.span("recovery.verify", cat="recovery"):
                cnt, chk = self._state_join_fingerprint()
            verified = (
                cnt == self.window_count and chk == self.window_checksum
            )
            if not verified:
                self._exhausted = True
                self._resolve_host_events(lost_hosts, recovered=False)
                raise RecoveryExhaustedError(
                    f"recovered state fails fingerprint verification at "
                    f"batch {bid}: joined ({cnt}, {chk:#010x}) != window "
                    f"({self.window_count}, {self.window_checksum:#010x})"
                )
        report = RecoveryReport(
            batch=bid,
            lost_hosts=tuple(sorted(lost_hosts)),
            lost_reducers=int(lost_ids.size),
            mode=mode,
            survivors=survivors,
            batches_replayed=len(self._retained_ids),
            replayed_tuples=replayed,
            lost_share_tuples=lost_share,
            migrated_tuples=migrated,
            reducers_before=reducers_before,
            reducers_after=self.plan.total_reducers if self.plan else 0,
            tenant=self.tenant,
            verified=verified,
        )
        self.recoveries.append(report)
        self.total_replayed += replayed
        record_recovery(self.obs, report)
        self._resolve_host_events(lost_hosts, recovered=True)
        self._log(
            f"[stream] recovered from loss of host(s) {sorted(lost_hosts)} "
            f"at batch {bid}: mode={mode}, {lost_ids.size} reducer(s), "
            f"replayed {replayed}/{lost_share} lineage tuples, "
            f"migrated {migrated}, survivors {survivors}/{hosts.provisioned}"
        )
        return report

    def _host_boundary(self, bid: int) -> None:
        """The per-batch recovery boundary: heal due partitions, fire
        scheduled host faults, heartbeat the live hosts into the detector
        (clocked in batch indices), and recover from any host the
        deadline declares lost."""
        hosts = self._hosts
        healed = hosts.heal_due(bid)
        if healed:
            self._log(
                f"[stream] partition healed at batch {bid}: host(s) "
                f"{healed} rejoin as empty spares"
            )
        if self._fault_injector is not None:
            for ev in self._fault_injector.fire_host_faults(bid, self.tenant):
                s = ev.spec
                heal = None if s.kind == "host_loss" else bid + s.heal_after
                hosts.silence(s.host_id, heal)
                self._pending_host_events.append(ev)
        members = set(self._detector.members)
        for h in hosts.alive:
            if h not in members:  # join-time registration: assume a beat
                self._detector.heartbeat(h, bid - 1)  # one batch ago
        for h in hosts.beating():
            self._detector.heartbeat(h, bid)
        lost = [h for h in self._detector.overdue(bid) if h in hosts.alive]
        if lost:
            self._recover(lost, bid)

    def fail_hosts(self, hosts_to_kill) -> RecoveryReport | None:
        """Kill hosts outright, outside the injector schedule (the demo /
        operational path: ``examples/streaming_join.py --kill-reducer``).
        Runs the same detect→recover boundary immediately and returns the
        resulting report (None if the kill removed no live host)."""
        if self._hosts is None:
            raise RuntimeError(
                "recovery is disabled: set StreamConfig.recovery = "
                "RecoveryPolicy(n_hosts=...)"
            )
        bid = len(self.reports)
        deadline = self.config.recovery.deadline_batches
        for h in hosts_to_kill:
            self._hosts.silence(int(h), None)
            if int(h) in self._detector.members:
                # an explicit kill is not a silent failure: rewind the
                # heartbeat past the deadline so detection fires NOW even
                # if the host beat at this same boundary already
                self._detector.heartbeat(int(h), bid - deadline)
        before = len(self.recoveries)
        self._host_boundary(bid)
        return self.recoveries[-1] if len(self.recoveries) > before else None

    # ---- delta join --------------------------------------------------------
    def _delta_join_sorted(
        self, new_routed: dict[str, _Routed], batch_id: int
    ) -> tuple[int, int]:
        """The telescoping terms via ``SortedDeltaIndex`` (binary joins on
        one shared column, fused path).  Evaluating term i against the
        index *after* relations < i appended their delta reproduces the
        all/new/old variant structure of the einsum path exactly; binned
        state is still maintained so replays and tests see one layout."""
        idx = self._delta_index
        names = self.spec.rel_names
        d_count, d_checksum = 0, 0
        for i, nm in enumerate(names):
            routed = new_routed[nm]
            if routed.dest.size:
                cnt, chk = idx.probe(names[1 - i], nm, routed.dest, routed.rows)
                d_count += cnt
                d_checksum = (d_checksum + chk) & _MASK32
            idx.append(nm, routed.dest, routed.rows, batch_id)
            self._state[nm] = self._scatter_any(self._state[nm], routed)
        return d_count, d_checksum

    def _delta_join(
        self, new_routed: dict[str, _Routed], batch_id: int
    ) -> tuple[int, int]:
        """Telescoping incremental join of the new emissions against carried
        state, then fold the batch into the state.  Returns
        (delta_count, delta_checksum)."""
        if self._delta_index is not None:
            return self._delta_join_sorted(new_routed, batch_id)
        k = self.plan.total_reducers
        variants: dict[str, dict[str, tuple[jnp.ndarray, jnp.ndarray]]] = {}
        merged: dict[str, tuple[np.ndarray, np.ndarray, np.ndarray]] = {}
        for rel in self.query.relations:
            nm = rel.name
            routed = new_routed[nm]
            nd, nrows = routed.dest, routed.rows
            ncap = _pow2(max(int(routed.counts.max()) if nd.size else 0, 1))
            if routed.rank is None:
                nbins, nvalid = _group_np(nd, nrows, k, ncap)
            else:  # fused pack plan: precomputed-index scatter, no sort
                nbins = np.zeros((k, ncap, nrows.shape[1]), dtype=np.int32)
                nvalid = np.zeros((k, ncap), dtype=bool)
                nbins[nd, routed.rank] = nrows
                nvalid[nd, routed.rank] = True
            obins, ovalid, _ = self._state[nm]
            merged[nm] = self._scatter_any(self._state[nm], routed)
            variants[nm] = {
                "old": (jnp.asarray(obins), jnp.asarray(ovalid)),
                "new": (jnp.asarray(nbins), jnp.asarray(nvalid)),
                "all": (jnp.asarray(merged[nm][0]), jnp.asarray(merged[nm][1])),
            }

        join_fn = (
            local_join_count_checksum_jit
            if self.config.fused_ingest
            else local_join_count_checksum
        )
        names = [r.name for r in self.query.relations]
        d_count, d_checksum = 0, 0
        for i, nm_i in enumerate(names):
            if new_routed[nm_i].dest.size == 0:
                continue  # ΔR_i empty -> term contributes nothing
            bins, valids = {}, {}
            for j, nm_j in enumerate(names):
                key = "all" if j < i else ("new" if j == i else "old")
                bins[nm_j], valids[nm_j] = variants[nm_j][key]
            cnt, chk = join_fn(self.spec, bins, valids)
            d_count += int(cnt)
            d_checksum = (d_checksum + int(np.uint32(chk))) & _MASK32
        self._state.update(merged)
        return d_count, d_checksum

    # ---- public API --------------------------------------------------------
    def ingest(
        self,
        batch: dict[str, np.ndarray],
        *,
        shared_deltas: dict[tuple[str, str], np.ndarray] | None = None,
    ) -> BatchReport:
        """Process one micro-batch; returns its telemetry.

        ``shared_deltas`` (multi-tenant mode, DESIGN.md §9): Count-Min
        table increments precomputed ONCE over this exact offered batch by
        a ``MultiQueryEngine`` shared ingest pass, keyed ``(attr,
        rel_name)``.  They are absorbed instead of running this engine's
        own sketch pass — bit-identical (integer counts are exact in
        float64) — but ONLY when the admitted rows equal the offered rows
        (empty backlog, nothing deferred or shed); a throttled tenant's
        sketch must see its own admitted subset, so it falls back to a
        private pass.

        With ``config.obs`` enabled (DESIGN.md §10) the batch runs under a
        root ``ingest`` span with the lifecycle phases nested inside, the
        per-batch metrics land in the shared registry, and the returned
        report's ``obs`` field carries the post-batch metrics + skew
        snapshots (compare-excluded; the deterministic fields still take
        part in the baseline-vs-fused parity assertions).
        """
        obs = self.obs
        obs.tracer.set_batch(len(self.reports))
        t0 = time.perf_counter()
        with obs.span("ingest", args={"tenant": self.tenant} if obs.tracer.enabled else None):
            report = self._ingest_inner(batch, shared_deltas)
        if obs.metrics.enabled or obs.skew is not None:
            if obs.metrics.enabled:
                self._record_batch_metrics(report, time.perf_counter() - t0)
            payload: dict = {}
            if obs.metrics.enabled:
                payload["metrics"] = obs.metrics.snapshot()
            if obs.skew is not None:
                payload["skew"] = obs.skew.snapshot().as_dict()
            report = dataclasses.replace(report, obs=payload)
            self.reports[-1] = report
        return report

    def _record_batch_metrics(self, report: BatchReport, seconds: float) -> None:
        """Fold one finished batch into the metrics registry (tenant label
        injected by the facade when this engine is a tenant view)."""
        obs = self.obs
        obs.counter("stream_batches_total").inc()
        obs.counter("stream_results_total").inc(report.delta_count)
        for rel in self.query.relations:
            n = report.comm_tuples.get(rel.name, 0)
            obs.counter("stream_comm_tuples_total", rel=rel.name).inc(n)
            # int32 rows: every shipped cell is 4 bytes (obs.skewscope)
            obs.counter("stream_comm_bytes_total", rel=rel.name).inc(
                n * rel.arity * 4
            )
        for nm, n in report.shed.items():
            if n:
                obs.counter("stream_shed_rows_total", rel=nm).inc(n)
        for nm, n in report.deferred.items():
            obs.gauge("stream_deferred_rows", rel=nm).set(n)
        if report.replanned:
            obs.counter(
                "stream_replan_total",
                trigger=report.drift_trigger or "initial",
            ).inc()
        if report.migrated_tuples:
            obs.counter("stream_migrated_tuples_total").inc(report.migrated_tuples)
        if report.expired_batches:
            obs.counter("stream_expired_batches_total").inc(report.expired_batches)
        if report.retracted_count:
            obs.counter("stream_retracted_results_total").inc(report.retracted_count)
        obs.gauge("stream_window_batches").set(len(self._retained_ids))
        obs.gauge("stream_carried_tuples").set(report.carried_tuples)
        obs.gauge("stream_max_load").set(report.max_load)
        obs.gauge("stream_plan_epoch").set(report.plan_epoch)
        obs.histogram("stream_batch_seconds").observe(seconds)

    def _ingest_inner(
        self,
        batch: dict[str, np.ndarray],
        shared_deltas: dict[tuple[str, str], np.ndarray] | None,
    ) -> BatchReport:
        if self._exhausted:
            raise RecoveryExhaustedError(
                "engine lost more hosts than the survivable grid; carried "
                "state is unrecoverable and ingest refuses to produce "
                "answers from it"
            )
        # validation FIRST: a poison batch must raise before any state
        # mutation so the engine stays resumable (DESIGN.md §9)
        offered = self._validate_batch(batch)
        now = self._clock()

        # 0. recovery boundary: heal partitions, fire scheduled host
        #    faults, detect and recover losses BEFORE the batch joins
        if self._hosts is not None:
            with self.obs.span("recovery.boundary", cat="recovery"):
                self._host_boundary(len(self.reports))

        # 1. admission: backlog + batch against the live budget
        if self._controller is not None:
            backlog_empty = all(
                arr.shape[0] == 0 for arr in self._controller.backlog.values()
            )
            with self.obs.span("admission"):
                admitted, decision = self._controller.admit(
                    offered, self.plan, self._concentration()
                )
            deferred, shed = decision.deferred, decision.shed
            pristine = (
                backlog_empty
                and decision.total_deferred == 0
                and decision.total_shed == 0
            )
        else:
            admitted = offered
            deferred = {nm: 0 for nm in offered}
            shed = {nm: 0 for nm in offered}
            pristine = True
        batch = {
            nm: np.ascontiguousarray(rows) for nm, rows in admitted.items()
        }
        use_shared = (
            shared_deltas is not None
            and pristine
            and all(
                (a, rel.name) in shared_deltas
                for rel in self.query.relations
                for a in self.tracker.attrs
                if a in rel.attrs
            )
        )

        # 2. retention: retire batches that left the window BEFORE this one
        #    joins, so new tuples only meet retained partners
        with self.obs.span("retention.expire"):
            expired_n, retracted = self._expire_due(now)

        # speculative routing under the plan that was live when the batch
        # arrived; discarded (and redone) only if this batch triggers a
        # replan, so the common case is ONE fused pass per relation
        spec_routes: dict[str, _Routed] = {}
        if use_shared:
            # absorb the MultiQueryEngine's shared CMS increments (computed
            # once over this exact batch) instead of a private sketch pass
            picked = {
                (a, rel.name): shared_deltas[(a, rel.name)]
                for rel in self.query.relations
                for a in self.tracker.attrs
                if a in rel.attrs
            }
            if self.config.fused_ingest:
                has_plan = self.plan is not None
                with self.obs.span("route.fused"):
                    for rel in self.query.relations:
                        routed, _ = self._fused_pass(
                            rel, batch[rel.name], with_route=has_plan,
                            with_sketch=False,
                        )
                        if routed is not None:
                            spec_routes[rel.name] = routed
                self.fused_batches += 1
            with self.obs.span("sketch.update", args={"shared": True}):
                self.tracker.observe_absorbed(batch, picked)
        elif self.config.fused_ingest:
            deltas: dict[tuple[str, str], np.ndarray] = {}
            has_plan = self.plan is not None
            # route + sketch increment are ONE fused pass per relation
            # (DESIGN.md §7); the span covers both halves of the taxonomy
            with self.obs.span("route.fused"):
                for rel in self.query.relations:
                    routed, d = self._fused_pass(
                        rel, batch[rel.name], with_route=has_plan, with_sketch=True
                    )
                    if d is not None:
                        for a, tbl in d.items():
                            deltas[(a, rel.name)] = tbl
                    if routed is not None:
                        spec_routes[rel.name] = routed
            with self.obs.span("sketch.update"):
                self.tracker.observe_absorbed(batch, deltas)
            self.fused_batches += 1
            self.sketch_ingest_calls += 1
        else:
            with self.obs.span("sketch.update"):
                self.tracker.observe(batch)
            self.sketch_ingest_calls += 1
        snapshot = self.tracker.snapshot(
            self._threshold(), self.config.max_hh_per_attr
        )
        hh = {a: s.values for a, s in snapshot.items()}

        replanned, reason, migrated = False, "", 0
        trigger, observed, threshold = "", 0.0, 0.0
        if self.plan is None:
            trigger = "initial"
            with self.obs.span("replan", args={"trigger": trigger}):
                with self.obs.span("replan.solve"):
                    plan = plan_with_hh(
                        self.query, batch, self.config.q, hh,
                        self.config.max_hh_per_attr,
                    )
                migrated = self._install(plan, batch)
            replanned, reason = True, "initial plan"
        else:
            pinned_rates = {
                (a, int(v)): float(self.tracker.rate_of(a, np.array([v]))[0])
                for a, vals in self.plan.hh_values.items()
                for v in np.asarray(vals).tolist()
            }
            with self.obs.span("drift.check"):
                decision: DriftDecision = self.monitor.check(
                    self.plan, self.query, batch, snapshot, pinned_rates
                )
            if decision.trigger:
                # recorded even when cooldown suppresses the replan, so the
                # trace tells "drifted but cooling down" from "no drift"
                self.obs.instant(
                    "drift.trigger",
                    args={
                        "trigger": decision.trigger,
                        "observed": decision.observed,
                        "threshold": decision.threshold,
                        "replan": decision.replan,
                    },
                )
            if decision.replan:
                trigger = decision.trigger
                observed, threshold = decision.observed, decision.threshold
                with self.obs.span("replan", args={"trigger": trigger}):
                    with self.obs.span("replan.solve"):
                        plan = plan_with_hh(
                            self.query, batch, self.config.q, hh,
                            self.config.max_hh_per_attr,
                        )
                    migrated = self._install(plan, batch)
                replanned, reason = True, decision.reason
                self._log(
                    f"[stream] replan epoch={self.plan_epoch} ({reason}); "
                    f"migrated {migrated} emissions"
                )
        if replanned:
            spec_routes = {}  # routed under the stale plan; redo below

        # route the new batch under the (possibly fresh) plan
        new_routed, comm = {}, {}
        skew = self.obs.skew
        with self.obs.span("route"):
            for rel in self.query.relations:
                routed = spec_routes.get(rel.name)
                if routed is None:
                    routed = self._route_any(rel, batch[rel.name])
                new_routed[rel.name] = routed
                comm[rel.name] = int(routed.dest.size)
                self._loads += routed.counts
                if skew is not None:
                    skew.record(rel.name, routed.counts)
        if skew is not None:
            skew.record_hh(*hh_hit_counts(self.query, batch, self.plan.hh_values))

        bid = len(self.reports)
        with self.obs.span("join.delta"):
            d_count, d_checksum = self._delta_join(new_routed, bid)
        self.total_count += d_count
        self.total_checksum = (self.total_checksum + d_checksum) & _MASK32
        self.window_count += d_count
        self.window_checksum = (self.window_checksum + d_checksum) & _MASK32
        self.cumulative_comm += sum(comm.values())

        # raw rows are kept only for replan migration; the binned reducer
        # state was already folded by _delta_join.  The routed log feeds
        # retraction and is kept only under retention.
        self._retained_ids.append(bid)
        self._batch_ts.append(now)
        for rel in self.query.relations:
            self._history[rel.name].append(batch[rel.name])
            if self.config.retention.enabled:
                self._routed_log[rel.name].append(new_routed[rel.name])

        carried, max_carried = carried_tuples(self._state)
        report = BatchReport(
            batch=bid,
            plan_epoch=self.plan_epoch,
            replanned=replanned,
            drift_reason=reason,
            delta_count=d_count,
            total_count=self.total_count,
            total_checksum=self.total_checksum,
            comm_tuples=comm,
            cumulative_comm=self.cumulative_comm,
            migrated_tuples=migrated,
            max_load=int(self._loads.max()) if self._loads.size else 0,
            hh_values={
                a: np.asarray(v).tolist() for a, v in self.plan.hh_values.items()
            },
            deferred=deferred,
            shed=shed,
            expired_batches=expired_n,
            retracted_count=retracted,
            window_count=self.window_count,
            window_checksum=self.window_checksum,
            carried_tuples=carried,
            max_carried=max_carried,
            drift_trigger=trigger,
            drift_observed=observed,
            drift_threshold=threshold,
        )
        self.reports.append(report)
        self._log(
            f"[stream] batch {report.batch}: +{d_count} results "
            f"(total {self.total_count}), comm {report.total_comm}, "
            f"hh {report.hh_values or '{}'}"
        )
        return report

    def history_data(self) -> dict[str, np.ndarray]:
        """The concatenation of every *retained* batch — the full stream
        when retention is off, the window suffix when it is on."""
        return {
            r.name: (
                np.concatenate(self._history[r.name], axis=0)
                if self._history[r.name]
                else np.zeros((0, r.arity), dtype=np.int64)
            )
            for r in self.query.relations
        }

    def recompute_distributed(self, window: bool = False, **kwargs):
        """Replay the retained input through the distributed shuffle under
        the current plan (correctness cross-check for carried state).

        With retention off this reproduces the cumulative fingerprint.
        With retention on and history expired, the full-stream input no
        longer exists — the replay covers the retained window only, whose
        reference is (``window_count``, ``window_checksum``); pass
        ``window=True`` to acknowledge that, otherwise this refuses rather
        than silently comparing a truncated replay against the full-stream
        fingerprint."""
        from repro.mapreduce.shuffle import run_distributed

        if self.plan is None:
            raise RuntimeError("no batches ingested yet")
        if self.expired_batches and not window:
            raise RuntimeError(
                f"retention has expired {self.expired_batches} batch(es): "
                "the retained window cannot reproduce the full-stream "
                "fingerprint (total_count/total_checksum).  Call "
                "recompute_distributed(window=True) to cross-check the "
                "retained suffix against (window_count, window_checksum)."
            )
        return run_distributed(self.query, self.history_data(), self.plan, **kwargs)

    @property
    def replan_count(self) -> int:
        """Drift-triggered replans (the initial plan does not count)."""
        return sum(1 for r in self.reports if r.replanned) - (1 if self.reports else 0)

    @property
    def total_deferred(self) -> int:
        return self._controller.total_deferred if self._controller else 0

    @property
    def total_shed(self) -> int:
        return self._controller.total_shed if self._controller else 0

    def skew_report(self):
        """The SkewScope snapshot with the Count-Min error audit folded in
        (DESIGN.md §10).  The audit walks the retained window computing
        decay-weighted exact counts, so it runs on demand here — not per
        ingest — keeping the per-batch obs cost flat."""
        skew = self.obs.skew
        if skew is None:
            raise RuntimeError(
                "skewscope is disabled: set StreamConfig.obs = "
                "ObsPolicy(skewscope=True)"
            )
        skew.record_cms_error(
            cms_window_error(
                self.tracker, self.query, self._history, self._retained_ids
            )
        )
        return skew.snapshot()

    # ---- checkpoint / restore (DESIGN.md §8) -------------------------------
    def save_checkpoint(self, directory: str, keep: int = 3) -> str:
        """Serialize the full engine state through ``train.checkpoint``
        (atomic step dir + LATEST pointer; step = batches ingested).
        Everything needed for a bit-identical resume goes in: sketches,
        drift-monitor baselines, retained history + window clock (stored as
        ages so TTL survives a clock rebase), admission backlog, incumbent
        plan and reports (pickled blobs), and the cumulative counters."""
        from repro.train.checkpoint import save_checkpoint as _save

        now = self._clock()
        tree: dict = {
            "scalars": np.array(
                [
                    self.total_count,
                    self.total_checksum,
                    self.window_count,
                    self.window_checksum,
                    self.cumulative_comm,
                    self.total_migrated,
                    self.expired_batches,
                    self.total_retracted,
                    self.plan_epoch,
                    self.fused_batches,
                ],
                dtype=np.int64,
            ),
            "loads": self._loads.astype(np.int64),
            "retained_ids": np.array(self._retained_ids, dtype=np.int64),
            "batch_ages": np.array(
                [now - ts for ts in self._batch_ts], dtype=np.float64
            ),
            "tracker": self.tracker.state_dict(),
            "monitor": self.monitor.state_dict(),
            "history": {
                nm: {f"{i:06d}": np.asarray(arr) for i, arr in enumerate(lst)}
                for nm, lst in self._history.items()
            },
            "blob": np.frombuffer(
                pickle.dumps((self.plan, self.reports)), dtype=np.uint8
            ).copy(),
        }
        if self._controller is not None:
            tree["admission"] = self._controller.state_dict()
        if self._hosts is not None:
            tree["hosts"] = self._hosts.state_dict()
            tree["recovery_scalars"] = np.array(
                [int(self._exhausted), self._slots_per_host, self.total_replayed],
                dtype=np.int64,
            )
            tree["recovery_blob"] = np.frombuffer(
                pickle.dumps(self.recoveries), dtype=np.uint8
            ).copy()
        with self.obs.span("checkpoint.save"):
            path = _save(
                directory,
                step=len(self.reports),
                tree=tree,
                keep=keep,
                metadata={
                    "kind": "stream_engine",
                    "format": CHECKPOINT_FORMAT,
                    "batches": len(self.reports),
                    "retained": len(self._retained_ids),
                },
            )
        if self.obs.metrics.enabled:
            import os

            nbytes = sum(
                os.path.getsize(os.path.join(dp, f))
                for dp, _, fs in os.walk(path)
                for f in fs
            )
            self.obs.counter("stream_checkpoints_total").inc()
            self.obs.counter("stream_checkpoint_bytes_total").inc(nbytes)
        return path

    @classmethod
    def restore(
        cls,
        directory: str,
        query: JoinQuery,
        config: StreamConfig,
        log_fn: Callable[[str], None] | None = None,
        clock: Callable[[], float] | None = None,
        step: int | None = None,
        obs: Observability | None = None,
    ) -> "StreamingJoinEngine":
        """Rebuild an engine mid-stream from a checkpoint.  ``query`` and
        ``config`` must match the saving engine (sketch shapes/seeds are
        config-derived).  Carried reducer state is reconstructed by
        re-routing the retained history under the restored plan — the same
        deterministic rebuild a replan migration performs — so subsequent
        batches produce bit-identical fingerprints to an uninterrupted
        run."""
        from repro.train.checkpoint import load_checkpoint, load_manifest

        manifest = load_manifest(directory, step)
        meta = manifest.get("metadata", {})
        if meta.get("kind") != "stream_engine":
            raise ValueError(f"not a stream engine checkpoint: {directory}")
        if meta.get("format") != CHECKPOINT_FORMAT:
            raise ValueError(
                f"checkpoint format {meta.get('format')} != "
                f"supported {CHECKPOINT_FORMAT}"
            )
        _, flat = load_checkpoint(directory, step)

        eng = cls(query, config, log_fn=log_fn, clock=clock, obs=obs)
        plan, reports = pickle.loads(flat["blob"].tobytes())
        eng.plan = plan
        eng.reports = list(reports)
        scalars = np.asarray(flat["scalars"]).tolist()
        (
            eng.total_count,
            eng.total_checksum,
            eng.window_count,
            eng.window_checksum,
            eng.cumulative_comm,
            eng.total_migrated,
            eng.expired_batches,
            eng.total_retracted,
            eng.plan_epoch,
            eng.fused_batches,
        ) = (int(s) for s in scalars)
        eng.tracker.load_state_dict(
            {
                k[len("tracker/") :]: v
                for k, v in flat.items()
                if k.startswith("tracker/")
            }
        )
        eng.monitor.load_state_dict({"scalars": flat["monitor/scalars"]})
        eng._retained_ids = [int(i) for i in flat["retained_ids"]]
        now = eng._clock()
        eng._batch_ts = [now - float(a) for a in flat["batch_ages"]]
        for rel in query.relations:
            prefix = f"history/{rel.name}/"
            keys = sorted(k for k in flat if k.startswith(prefix))
            eng._history[rel.name] = [
                np.asarray(flat[k]).reshape(-1, rel.arity) for k in keys
            ]
            if len(eng._history[rel.name]) != len(eng._retained_ids):
                raise ValueError("checkpoint history/window length mismatch")
        if eng._controller is not None:
            eng._controller.load_state_dict(
                {
                    k[len("admission/") :]: v
                    for k, v in flat.items()
                    if k.startswith("admission/")
                }
            )
        if eng._hosts is not None and "hosts/alive" in flat:
            eng._hosts.load_state_dict(
                {
                    k[len("hosts/") :]: v
                    for k, v in flat.items()
                    if k.startswith("hosts/")
                }
            )
            rs = np.asarray(flat["recovery_scalars"]).tolist()
            eng._exhausted = bool(rs[0])
            eng._slots_per_host = int(rs[1])
            eng.total_replayed = int(rs[2])
            eng.recoveries = pickle.loads(flat["recovery_blob"].tobytes())
        if eng.plan is not None:
            eng._rebuild_routed_state()
            if eng._hosts is not None and (
                eng._hosts.host_of.size != eng.plan.total_reducers
            ):  # pre-recovery checkpoint: place reducers fresh
                eng._hosts.assign(eng.plan.total_reducers)
        # loads are arrivals-per-epoch telemetry (they include expired and
        # migrated arrivals), not derivable from the retained rebuild
        eng._loads = np.asarray(flat["loads"]).astype(np.int64)
        return eng
