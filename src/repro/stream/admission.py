"""Backpressure / admission control on streaming ingest (DESIGN.md §8).

The paper's capacity bound ``q`` is solved per batch: the plan guarantees
per-reducer arrivals ≈ q *for the batch size it was planned against*.  An
overloaded producer can hand the engine a batch far larger than that, and
the §6 engine would ship it anyway — blowing the VMEM/time budget the plan
was solved for.  Admission control turns overload into graceful
degradation with **exact accounting**:

  * Per relation, the per-batch admission budget is derived from the live
    plan and sketch: a plan with K reducers and replication width W_rel
    spreads ``n`` admitted rows into ~``n * W_rel / K`` arrivals per
    reducer, so the budget is ``headroom * q * K / W_rel`` rows — the
    largest batch the running plan can absorb within ``headroom`` × its
    solved capacity.  When the sketch predicts a *concentrated* hot value
    (an unpinned heavy hitter hashes to one grid coordinate — the overload
    signal of ``stream.drift``), the budget is tightened by the predicted
    concentration factor so a skewed inflow is throttled harder than a
    uniform one.
  * Arrivals beyond the budget are **deferred**: queued in a FIFO backlog,
    re-offered ahead of the next batch.  Joins are multiset-associative,
    so deferral never loses or duplicates results — it only shifts which
    batch emits them; the cumulative fingerprint after the backlog drains
    equals the oracle on everything admitted.
  * A backlog beyond ``max_backlog_rows`` is **shed** oldest-first, each
    drop counted per relation (``BatchReport.shed``).  Shedding is the
    only lossy action in the engine and is always explicit — the counters
    are exact, never sampled.

Everything is off by default (``AdmissionPolicy()`` admits unconditionally)
so the §6 baseline behavior is unchanged unless configured.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping

import numpy as np

from repro.core.planner import SharesSkewPlan
from repro.core.schema import JoinQuery


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Knobs for per-batch admission.  ``headroom=None`` (default) disables
    admission control entirely (admit everything, defer/shed nothing)."""

    headroom: float | None = None  # budget = headroom * q * K / W_rel rows
    max_backlog_rows: int = 100_000  # per relation; beyond this, shed
    min_admit: int = 32  # never starve a relation below this many rows

    def __post_init__(self):
        if self.headroom is not None and (
            not math.isfinite(self.headroom) or self.headroom <= 0
        ):
            raise ValueError(
                f"headroom must be finite and > 0, got {self.headroom}"
            )
        if self.max_backlog_rows < 0:
            raise ValueError("max_backlog_rows must be >= 0")
        if self.min_admit < 1:
            raise ValueError("min_admit must be >= 1")

    @property
    def enabled(self) -> bool:
        return self.headroom is not None


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """Exact per-relation accounting for one batch boundary."""

    admitted: dict[str, int]  # rows entering the engine this batch
    deferred: dict[str, int]  # rows left queued in the backlog
    shed: dict[str, int]  # rows dropped (permanently) this batch
    budget: dict[str, int]  # the budget each relation was held to

    @property
    def total_deferred(self) -> int:
        return int(sum(self.deferred.values()))

    @property
    def total_shed(self) -> int:
        return int(sum(self.shed.values()))


def replication_width(plan: SharesSkewPlan, rel_name: str) -> int:
    """Total map-phase emission width of one relation under ``plan`` —
    Σ over residuals of the integer-share replication, i.e. the W in
    ``map_phase``'s [N, W] destination block."""
    rel = next(r for r in plan.query.relations if r.name == rel_name)
    return max(
        1, sum(res.int_replication(rel.attrs) for res in plan.residuals)
    )


class AdmissionController:
    """Stateless budget math + stateful FIFO backlog per relation."""

    def __init__(self, policy: AdmissionPolicy, query: JoinQuery, q: float):
        if not math.isfinite(q) or q <= 0:
            raise ValueError(
                f"admission needs a finite positive capacity q, got {q} "
                "(a zero/NaN q would silently zero every budget)"
            )
        self.policy = policy
        self.query = query
        self.q = float(q)
        self.backlog: dict[str, np.ndarray] = {
            r.name: np.zeros((0, r.arity), dtype=np.int64)
            for r in query.relations
        }
        self.total_deferred = 0  # rows that waited at least one batch
        self.total_shed = 0
        # degraded-mode tightening (DESIGN.md §5): after reducer loss the
        # engine sets this to surviving/provisioned host capacity, so
        # budgets shrink proportionally with the cluster — beyond the K/W
        # shrink the repaired plan already causes
        self.capacity_factor = 1.0

    def set_capacity(self, factor: float) -> None:
        """Clamp admission to ``factor`` x the healthy-cluster budget
        (0 < factor <= 1; 1.0 restores full capacity).  NaN and
        non-positive factors are rejected loudly — a NaN would otherwise
        poison every subsequent budget into ``min_admit`` floor values."""
        if not math.isfinite(factor):
            raise ValueError(f"capacity factor must be finite, got {factor}")
        if not 0.0 < factor <= 1.0:
            raise ValueError(f"capacity factor must be in (0, 1], got {factor}")
        self.capacity_factor = float(factor)

    # ---- budget ------------------------------------------------------------
    def budgets(
        self,
        plan: SharesSkewPlan | None,
        concentration: float = 1.0,
    ) -> dict[str, int]:
        """Per-relation row budgets for the next batch.  ``concentration``
        is the sketch's predicted worst per-reducer load ÷ q for the
        current inflow (>= 1 tightens the budget): a hot unpinned value
        concentrates arrivals, so fewer rows fit the same capacity."""
        if not self.policy.enabled or plan is None:
            return {r.name: np.iinfo(np.int64).max for r in self.query.relations}
        k = max(1, plan.total_reducers)
        out = {}
        for rel in self.query.relations:
            w = replication_width(plan, rel.name)
            budget = self.policy.headroom * self.q * k / w
            budget /= max(1.0, float(concentration))
            budget *= self.capacity_factor
            out[rel.name] = max(self.policy.min_admit, int(budget))
        return out

    # ---- admission ---------------------------------------------------------
    def admit(
        self,
        batch: Mapping[str, np.ndarray],
        plan: SharesSkewPlan | None,
        concentration: float = 1.0,
    ) -> tuple[dict[str, np.ndarray], AdmissionDecision]:
        """Split (backlog ++ batch) into admitted rows (FIFO, backlog
        first) and a new backlog; shed backlog overflow oldest-first.
        Returns (admitted rows per relation, exact accounting)."""
        budgets = self.budgets(plan, concentration)
        admitted_rows: dict[str, np.ndarray] = {}
        admitted_n, deferred_n, shed_n, budget_rep = {}, {}, {}, {}
        for rel in self.query.relations:
            nm = rel.name
            pending = np.concatenate(
                [self.backlog[nm], np.asarray(batch[nm]).reshape(-1, rel.arity)],
                axis=0,
            )
            b = budgets[nm]
            take = min(len(pending), b)
            admitted_rows[nm] = pending[:take]
            rest = pending[take:]
            over = max(0, len(rest) - self.policy.max_backlog_rows)
            if over:
                rest = rest[over:]  # shed oldest-first
            self.backlog[nm] = rest
            admitted_n[nm] = int(take)
            deferred_n[nm] = int(len(rest))
            shed_n[nm] = int(over)
            budget_rep[nm] = int(min(b, np.iinfo(np.int64).max))
        decision = AdmissionDecision(admitted_n, deferred_n, shed_n, budget_rep)
        self.total_deferred += decision.total_deferred
        self.total_shed += decision.total_shed
        return admitted_rows, decision

    # ---- checkpoint --------------------------------------------------------
    def state_dict(self) -> dict[str, np.ndarray]:
        out = {f"backlog/{nm}": arr for nm, arr in self.backlog.items()}
        out["totals"] = np.array(
            [self.total_deferred, self.total_shed], dtype=np.int64
        )
        out["capacity"] = np.array([self.capacity_factor], dtype=np.float64)
        return out

    def load_state_dict(self, state: Mapping[str, np.ndarray]) -> None:
        for nm in self.backlog:
            self.backlog[nm] = np.asarray(state[f"backlog/{nm}"])
        totals = np.asarray(state["totals"])
        self.total_deferred = int(totals[0])
        self.total_shed = int(totals[1])
        if "capacity" in state:  # absent in pre-recovery checkpoints
            self.capacity_factor = float(np.asarray(state["capacity"])[0])


# ---- multi-tenant fair share (DESIGN.md §9) --------------------------------
def weighted_fair_allocation(
    demands: Mapping[str, float],
    weights: Mapping[str, float],
    capacity: float,
) -> dict[str, float]:
    """Weighted max-min fair allocation (water-filling).

    Splits ``capacity`` (in the same units as ``demands`` — the engine uses
    predicted reducer arrivals, rows x replication width) across tenants:
    a tenant whose demand fits under its weighted share keeps ALL of it,
    and the freed surplus is re-divided among the still-hungry tenants by
    weight.  The classic invariants hold: no tenant gets more than its
    demand, the allocation is work-conserving (sum == min(capacity, total
    demand)), and when aggregate demand fits, allocation == demand for
    everyone — overload control is invisible until there is overload.
    """
    if not math.isfinite(capacity) or capacity < 0:
        raise ValueError(f"capacity must be finite and >= 0, got {capacity}")
    for t, w in weights.items():
        if not math.isfinite(w) or w <= 0:
            raise ValueError(f"tenant {t!r} weight must be finite > 0, got {w}")
    for t, d in demands.items():
        if not math.isfinite(d) or d < 0:
            raise ValueError(f"tenant {t!r} demand must be finite >= 0, got {d}")
    alloc = {t: 0.0 for t in demands}
    active = sorted(t for t in demands if demands[t] > 0)
    remaining = float(capacity)
    while active and remaining > 1e-12:
        wsum = sum(weights.get(t, 1.0) for t in active)
        share = {t: remaining * weights.get(t, 1.0) / wsum for t in active}
        satisfied = [t for t in active if demands[t] - alloc[t] <= share[t]]
        if not satisfied:
            for t in active:
                alloc[t] += share[t]
            break
        for t in satisfied:
            take = demands[t] - alloc[t]
            alloc[t] = demands[t]
            remaining -= take
        active = [t for t in active if t not in satisfied]
    return alloc


class FairShareController:
    """Aggregate overload control across tenants (DESIGN.md §9).

    Each batch, every tenant's *demand* is its offered rows weighted by the
    replication width of its live plan (the per-query communication budget
    of Beame-Koutris-Suciu: what the tenant will actually ship).  When the
    aggregate demand exceeds ``capacity`` predicted arrivals per batch, the
    weighted max-min allocation above decides who is trimmed; tenants under
    their fair share are never touched, so overload on one tenant cannot
    perturb a well-behaved neighbor's rows (the isolation contract the
    tenancy tests assert bit-for-bit).  Trimming is counted per tenant as
    ``overload_shed`` rows plus a ``backpressure`` event per trimmed batch
    — exact counters, same contract as ``AdmissionController``.

    ``capacity=None`` disables aggregate control (every tenant admitted in
    full; per-tenant ``AdmissionController``s still apply downstream).
    """

    def __init__(
        self,
        capacity: float | None,
        weights: Mapping[str, float],
    ):
        if capacity is not None and (
            not math.isfinite(capacity) or capacity <= 0
        ):
            raise ValueError(
                f"aggregate capacity must be finite and > 0, got {capacity}"
            )
        for t, w in weights.items():
            if not math.isfinite(w) or w <= 0:
                raise ValueError(
                    f"tenant {t!r} weight must be finite > 0, got {w}"
                )
        self.capacity = None if capacity is None else float(capacity)
        self.weights = {t: float(w) for t, w in weights.items()}
        self.overload_shed: dict[str, int] = {t: 0 for t in weights}
        self.backpressure: dict[str, int] = {t: 0 for t in weights}

    def fractions(self, demands: Mapping[str, float]) -> dict[str, float]:
        """Admitted fraction per tenant for one batch (1.0 = untrimmed)."""
        if self.capacity is None:
            return {t: 1.0 for t in demands}
        total = sum(demands.values())
        if total <= self.capacity:
            return {t: 1.0 for t in demands}
        alloc = weighted_fair_allocation(demands, self.weights, self.capacity)
        return {
            t: (alloc[t] / demands[t]) if demands[t] > 0 else 1.0
            for t in demands
        }

    def record_trim(self, tenant: str, rows_trimmed: int) -> None:
        if rows_trimmed > 0:
            self.overload_shed[tenant] = (
                self.overload_shed.get(tenant, 0) + int(rows_trimmed)
            )
            self.backpressure[tenant] = self.backpressure.get(tenant, 0) + 1

    # ---- checkpoint --------------------------------------------------------
    def state_dict(self) -> dict[str, np.ndarray]:
        names = sorted(self.weights)
        return {
            "shed": np.array(
                [self.overload_shed.get(t, 0) for t in names], np.int64
            ),
            "backpressure": np.array(
                [self.backpressure.get(t, 0) for t in names], np.int64
            ),
        }

    def load_state_dict(self, state: Mapping[str, np.ndarray]) -> None:
        names = sorted(self.weights)
        shed = np.asarray(state["shed"])
        bp = np.asarray(state["backpressure"])
        if shed.size != len(names) or bp.size != len(names):
            raise ValueError("fair-share checkpoint tenant count mismatch")
        self.overload_shed = {t: int(s) for t, s in zip(names, shed)}
        self.backpressure = {t: int(b) for t, b in zip(names, bp)}
