"""Mergeable, decaying stream summaries for online heavy-hitter tracking
(DESIGN.md §6).

The batch planner sees all data up front and finds heavy hitters with one
exact scan (``core.heavy_hitters.exact_heavy_hitters``).  A streaming join
never sees "all data": the skew profile must be maintained incrementally
and must *forget*, so a value that was heavy an hour ago stops forcing a
pinned residual today.  Three layers:

  * ``DecayingCountMin`` — a ``core.heavy_hitters.CountMinSketch`` with a
    mix32 hash family (bit-identical on host numpy and on device via
    ``kernels.cms_update``) and exponential decay: before each batch the
    table is scaled by ``decay``, so counts converge to an EMA of per-batch
    frequencies.  ``rate()`` is the bias-corrected per-batch rate estimate.
  * ``SpaceSaving`` — Metwally et al.'s stream-summary with a fixed number
    of counters; generates the candidate set (CMS alone cannot enumerate
    which values to ask about).  Mergeable and decayable the same way.
  * ``StreamHHTracker`` — per share-attribute SpaceSaving candidates plus
    per (attribute, relation) DecayingCountMin rates, combined exactly like
    the batch detector: a value is a live HH when its estimated per-batch
    rate in ANY relation containing the attribute reaches the threshold.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dominance import share_attributes
from repro.core.heavy_hitters import CountMinSketch
from repro.core.schema import JoinQuery
from repro.mapreduce.hashing import bucket_np


def _row_seeds(seed: int, depth: int) -> tuple[int, ...]:
    """Per-row mix32 seeds, reproducible from one integer seed."""
    rng = np.random.default_rng(seed)
    return tuple(int(s) for s in rng.integers(1, (1 << 31) - 1, size=depth))


def cms_delta(col: np.ndarray, seeds: tuple[int, ...], width: int) -> np.ndarray:
    """One column's [depth, width] Count-Min bucket-count increment.

    Integer counts over the mix32 family — bit-identical to what
    ``DecayingCountMin.update`` would add for the same column, so the
    result can be ``absorb``-ed by any sketch sharing ``(seeds, width)``.
    This is how a ``MultiQueryEngine`` computes ONE shared increment per
    relation batch and hands it to every tenant's tracker (DESIGN.md §9).
    """
    delta = np.zeros((len(seeds), int(width)), dtype=np.float64)
    col = np.asarray(col, dtype=np.int64)
    if col.size:
        for d, s in enumerate(seeds):
            buckets = bucket_np(col, s, int(width))
            delta[d] = np.bincount(buckets, minlength=int(width))
    return delta


class DecayingCountMin(CountMinSketch):
    """Count-Min over the mix32 row family with exponential decay.

    The bucket function matches ``kernels.cms_update`` bit-for-bit, so the
    per-batch table increment can be produced on-device and absorbed here.
    The table is float64: after ``step()`` it holds
    ``sum_t decay^(T-t) * c_t`` per bucket — a geometric average whose
    bias-corrected normalization ``(1-decay)/(1-decay^T)`` turns estimates
    into per-batch rates.
    """

    def __init__(
        self, width: int = 2048, depth: int = 4, seed: int = 0, decay: float = 0.5
    ):
        if not (0.0 < decay <= 1.0):
            raise ValueError("decay must be in (0, 1]")
        self.width = int(width)
        self.depth = int(depth)
        self.seeds = _row_seeds(seed, depth)
        self.decay_factor = float(decay)
        self.table = np.zeros((depth, width), dtype=np.float64)
        self.total = 0.0
        self.batches = 0

    # mix32 family instead of the Mersenne universal hashes of the parent
    def _buckets(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, dtype=np.int64)
        return np.stack([bucket_np(keys, s, self.width) for s in self.seeds])

    def step(self) -> None:
        """Advance one batch boundary: decay everything seen so far."""
        if self.decay_factor < 1.0:
            self.table *= self.decay_factor
            self.total *= self.decay_factor
        self.batches += 1

    def absorb(self, delta_table: np.ndarray, n: int) -> None:
        """Add a [depth, width] increment (e.g. from ``kernels.cms_update``)."""
        if delta_table.shape != self.table.shape:
            raise ValueError("increment shape must match sketch table")
        self.table += delta_table
        self.total += float(n)

    def rate(self, keys: np.ndarray) -> np.ndarray:
        """Bias-corrected per-batch rate estimates (upper bounds)."""
        if self.batches == 0:
            return np.zeros(np.asarray(keys).size)
        g = self.decay_factor
        norm = 1.0 / self.batches if g >= 1.0 else (1.0 - g) / (1.0 - g**self.batches)
        return self.estimate(keys) * norm

    def merge(self, other: "DecayingCountMin") -> "DecayingCountMin":
        if (self.width, self.depth) != (other.width, other.depth):
            raise ValueError("sketch shapes must match to merge")
        if self.seeds != other.seeds or self.decay_factor != other.decay_factor:
            raise ValueError("sketch seeds/decay must match to merge")
        out = DecayingCountMin(self.width, self.depth, decay=self.decay_factor)
        out.seeds = self.seeds
        out.table = self.table + other.table
        out.total = self.total + other.total
        out.batches = max(self.batches, other.batches)
        return out

    # ---- checkpoint (DESIGN.md §8) -----------------------------------------
    def state_dict(self) -> dict[str, np.ndarray]:
        return {
            "table": self.table.copy(),
            "scalars": np.array([self.total, float(self.batches)], np.float64),
        }

    def load_state_dict(self, state: dict[str, np.ndarray]) -> None:
        table = np.asarray(state["table"], dtype=np.float64)
        if table.shape != self.table.shape:
            raise ValueError("checkpointed sketch table shape mismatch")
        self.table = table.copy()
        scalars = np.asarray(state["scalars"])
        self.total = float(scalars[0])
        self.batches = int(scalars[1])


class SpaceSaving:
    """Stream-summary with ``capacity`` counters (Metwally et al. 2005).

    Guarantees: every value with true (decayed) count > total/capacity is
    retained; ``counts[v]`` overestimates by at most ``errors[v]``.
    """

    def __init__(self, capacity: int = 64):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.counts: dict[int, float] = {}
        self.errors: dict[int, float] = {}

    def update(self, keys: np.ndarray) -> None:
        keys = np.asarray(keys, dtype=np.int64)
        if keys.size == 0:
            return
        vals, cnts = np.unique(keys, return_counts=True)
        # largest first so evictions never displace a bigger newcomer
        order = np.argsort(-cnts, kind="stable")
        for v, c in zip(vals[order].tolist(), cnts[order].tolist()):
            if v in self.counts:
                self.counts[v] += c
            elif len(self.counts) < self.capacity:
                self.counts[v] = float(c)
                self.errors[v] = 0.0
            else:
                victim = min(self.counts, key=self.counts.__getitem__)
                floor = self.counts.pop(victim)
                self.errors.pop(victim)
                self.counts[v] = floor + c
                self.errors[v] = floor

    def decay(self, factor: float) -> None:
        for v in self.counts:
            self.counts[v] *= factor
            self.errors[v] *= factor

    def merge(self, other: "SpaceSaving") -> "SpaceSaving":
        out = SpaceSaving(self.capacity)
        for src in (self, other):
            for v, c in src.counts.items():
                out.counts[v] = out.counts.get(v, 0.0) + c
                out.errors[v] = out.errors.get(v, 0.0) + src.errors[v]
        if len(out.counts) > out.capacity:
            keep = sorted(out.counts, key=out.counts.__getitem__, reverse=True)
            for v in keep[out.capacity :]:
                del out.counts[v], out.errors[v]
        return out

    def candidates(self) -> tuple[np.ndarray, np.ndarray]:
        """(values, counts) sorted by count descending."""
        if not self.counts:
            return np.empty(0, np.int64), np.empty(0, np.float64)
        items = sorted(self.counts.items(), key=lambda kv: -kv[1])
        vals = np.array([v for v, _ in items], dtype=np.int64)
        cnts = np.array([c for _, c in items], dtype=np.float64)
        return vals, cnts

    # ---- checkpoint (DESIGN.md §8) -----------------------------------------
    def state_dict(self) -> dict[str, np.ndarray]:
        """Counters in *insertion order* — eviction and candidate ordering
        tie-break on it, so preserving it makes restore bit-deterministic."""
        vals = np.array(list(self.counts), dtype=np.int64)
        return {
            "values": vals,
            "counts": np.array([self.counts[v] for v in vals], np.float64),
            "errors": np.array([self.errors[v] for v in vals], np.float64),
        }

    def load_state_dict(self, state: dict[str, np.ndarray]) -> None:
        vals = np.asarray(state["values"], dtype=np.int64)
        if vals.size > self.capacity:
            raise ValueError("checkpointed SpaceSaving exceeds capacity")
        self.counts = {
            int(v): float(c) for v, c in zip(vals, np.asarray(state["counts"]))
        }
        self.errors = {
            int(v): float(e) for v, e in zip(vals, np.asarray(state["errors"]))
        }


@dataclasses.dataclass(frozen=True)
class HHSnapshot:
    """Live heavy-hitter view for one attribute."""

    attr: str
    values: np.ndarray  # candidate values, rate-descending
    rates: np.ndarray  # per-batch rate estimates (max over relations)


class StreamHHTracker:
    """Per-attribute HH candidate tracking across micro-batches.

    ``observe(batch)`` decays all summaries one step and folds in the
    batch's join-attribute columns; ``snapshot()`` returns, per share
    attribute, candidates whose estimated per-batch rate crosses the
    threshold — the streaming analogue of ``detect_heavy_hitters``.
    """

    def __init__(
        self,
        query: JoinQuery,
        width: int = 2048,
        depth: int = 4,
        capacity: int = 64,
        decay: float = 0.5,
        seed: int = 0,
        use_device_sketch: bool = False,
    ):
        self.query = query
        self.attrs = share_attributes(query)
        self.decay = float(decay)
        self.width = int(width)
        self.seeds = _row_seeds(seed, depth)  # shared by every CMS below
        self.use_device_sketch = bool(use_device_sketch)
        self._ss = {a: SpaceSaving(capacity) for a in self.attrs}
        self._cms: dict[tuple[str, str], DecayingCountMin] = {}
        for a in self.attrs:
            for rel in query.relations_of(a):
                self._cms[(a, rel.name)] = DecayingCountMin(
                    width, depth, seed=seed, decay=decay
                )
        self.batches = 0

    def observe(self, batch: dict[str, np.ndarray]) -> None:
        for cms in self._cms.values():
            cms.step()
        for a in self.attrs:
            self._ss[a].decay(self.decay)
        for a in self.attrs:
            for rel in self.query.relations_of(a):
                col = np.asarray(batch[rel.name])[:, rel.index_of(a)]
                cms = self._cms[(a, rel.name)]
                if self.use_device_sketch and col.size:
                    import jax.numpy as jnp

                    from repro.kernels import cms_update

                    delta = np.asarray(
                        cms_update(
                            jnp.asarray(col, dtype=jnp.int32), cms.seeds, cms.width
                        )
                    )
                    cms.absorb(delta.astype(np.float64), col.size)
                else:
                    cms.update(col)
                self._ss[a].update(col)
        self.batches += 1

    def observe_absorbed(
        self,
        batch: dict[str, np.ndarray],
        deltas: dict[tuple[str, str], np.ndarray],
    ) -> None:
        """``observe`` with the Count-Min increments precomputed elsewhere.

        ``deltas[(attr, rel_name)]`` is the [depth, width] bucket-count
        increment for that column — e.g. from the fused ingest kernel
        (``kernels.ingest_fused``), which shares this tracker's ``seeds``
        so tables stay bit-identical to the host ``observe`` path
        (integer counts are exact in float64).  SpaceSaving candidate
        tracking still runs host-side: it is a tiny dict update and needs
        the raw values, which the sketch buckets discard.
        """
        for cms in self._cms.values():
            cms.step()
        for a in self.attrs:
            self._ss[a].decay(self.decay)
        for a in self.attrs:
            for rel in self.query.relations_of(a):
                col = np.asarray(batch[rel.name])[:, rel.index_of(a)]
                self._cms[(a, rel.name)].absorb(
                    np.asarray(deltas[(a, rel.name)], dtype=np.float64), col.size
                )
                self._ss[a].update(col)
        self.batches += 1

    def candidates_of(self, attr: str) -> tuple[np.ndarray, np.ndarray]:
        """Public view of the SpaceSaving candidate set for ``attr`` —
        (values, decayed counts), count-descending.  This is the value set
        planning decisions are made from, and the set ``obs.skewscope``
        audits the sketch against."""
        return self._ss[attr].candidates()

    def rate_in(self, attr: str, rel_name: str, values: np.ndarray) -> np.ndarray:
        """Per-batch rate estimates for ``values`` in ONE relation's
        sketch.  ``rate_of`` takes the max over relations (the planning
        view); the CMS-error audit in ``obs.skewscope`` needs the
        per-relation estimate that exact per-relation counts compare to."""
        values = np.asarray(values, dtype=np.int64)
        if values.size == 0:
            return np.empty(0, np.float64)
        return self._cms[(attr, rel_name)].rate(values)

    def rate_of(self, attr: str, values: np.ndarray) -> np.ndarray:
        """Max per-batch rate over relations containing ``attr``."""
        values = np.asarray(values, dtype=np.int64)
        if values.size == 0:
            return np.empty(0, np.float64)
        rates = [
            self._cms[(attr, rel.name)].rate(values)
            for rel in self.query.relations_of(attr)
        ]
        return np.max(np.stack(rates), axis=0)

    def snapshot(self, threshold: float, max_per_attr: int = 8) -> dict[str, HHSnapshot]:
        out: dict[str, HHSnapshot] = {}
        for a in self.attrs:
            cand, _ = self._ss[a].candidates()
            if cand.size == 0:
                continue
            rates = self.rate_of(a, cand)
            mask = rates >= threshold
            if not mask.any():
                continue
            vals, rates = cand[mask], rates[mask]
            order = np.argsort(-rates, kind="stable")[:max_per_attr]
            out[a] = HHSnapshot(a, vals[order], rates[order])
        return out

    def hh_values(self, threshold: float, max_per_attr: int = 8) -> dict[str, np.ndarray]:
        """The ``plan_with_hh``-shaped view of ``snapshot``."""
        return {
            a: s.values for a, s in self.snapshot(threshold, max_per_attr).items()
        }

    # ---- checkpoint (DESIGN.md §8) -----------------------------------------
    def state_dict(self) -> dict[str, np.ndarray]:
        """Flat array tree of every summary — restoring it into a tracker
        built from the same config resumes estimation bit-for-bit."""
        out: dict[str, np.ndarray] = {
            "batches": np.array([self.batches], np.int64)
        }
        for (a, rel_name), cms in self._cms.items():
            for k, v in cms.state_dict().items():
                out[f"cms/{a}/{rel_name}/{k}"] = v
        for a, ss in self._ss.items():
            for k, v in ss.state_dict().items():
                out[f"ss/{a}/{k}"] = v
        return out

    def load_state_dict(self, state: dict[str, np.ndarray]) -> None:
        self.batches = int(np.asarray(state["batches"])[0])
        for (a, rel_name), cms in self._cms.items():
            cms.load_state_dict(
                {
                    "table": state[f"cms/{a}/{rel_name}/table"],
                    "scalars": state[f"cms/{a}/{rel_name}/scalars"],
                }
            )
        for a, ss in self._ss.items():
            ss.load_state_dict(
                {
                    "values": state[f"ss/{a}/values"],
                    "counts": state[f"ss/{a}/counts"],
                    "errors": state[f"ss/{a}/errors"],
                }
            )
