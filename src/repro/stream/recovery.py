"""Reducer-loss recovery for the streaming engine (DESIGN.md §5).

The shares assignment deliberately concentrates heavy-hitter work on
specific reducers — so losing the host that carries them loses exactly
the state that is most expensive to rebuild.  Before this subsystem the
only remedy was a full checkpoint restore (DESIGN.md §8); recovery
instead runs in-flight, at batch boundaries, through four stages:

  1. **detection** — logical reducers are multiplexed over simulated
     hosts (contiguous blocks, ``HostTracker``); every live host
     heartbeats once per ingested batch into a
     ``mapreduce.straggler.FailureDetector`` clocked in *batch indices*
     (deterministic under test), and a host ``deadline_batches`` behind
     is declared lost;
  2. **repair** — if the surviving fraction stays above
     ``degrade_below``, the incumbent plan is untouched (same grid, same
     HH combinations) and the lost logical reducers are simply remapped
     onto survivors; under sustained loss, ``core.planner.repair_plan``
     re-projects the incumbent shares onto a grid sized by
     ``train.elastic.plan_mesh_shape`` for the surviving hosts — HH
     combinations never move, each residual's grid shrinks in place;
  3. **replay** — the lost reducers' carried state is reconstructed by
     *lineage replay* from the retained per-batch window: each retained
     batch's routed emissions are filtered to the lost destinations and
     re-scattered in batch order, reproducing the dead bins
     bit-for-bit.  Replayed tuples == the lost reducers' retained-window
     share; nothing else moves — no full-stream re-route, no checkpoint
     read;
  4. **degrade** — in degraded mode admission budgets additionally
     tighten by the surviving-capacity fraction
     (``AdmissionController.set_capacity``), and when the survivors
     cannot host even one reducer per residual combination, recovery is
     *exhausted*: ``RecoveryExhaustedError`` — an explicit, loud error,
     never a silently wrong window.

Every recovery is verified exact on the spot: the recovered binned state
is re-joined through the einsum oracle and its (count, checksum) must
equal the maintained window fingerprint bit-for-bit (the same invariant
``recompute_distributed(window=True)`` checks externally).

Cost model (PAPERS.md, Beame–Koutris–Suciu arXiv:1401.1872): with L of K
reducers lost and per-relation window loads W_rel, lineage replay ships
``sum_rel (L/K) * W_rel`` tuples in one round — an L/K fraction of the
retained window — versus a full restore's ``sum_rel W_rel`` plus the
checkpoint read.  See DESIGN.md §5 for the derivation.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class RecoveryExhaustedError(RuntimeError):
    """Loss beyond the survivable grid: the remaining hosts cannot carry a
    correct repaired plan (fewer survivors than ``min_hosts``, or fewer
    reducer slots than residual combinations).  Raised at the failure
    boundary and again on any subsequent ``ingest`` — an exhausted engine
    refuses to produce answers rather than produce wrong ones."""


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Recovery knobs.  ``n_hosts=None`` (default) disables the host model
    entirely, reproducing the pre-recovery engine bit-for-bit."""

    n_hosts: int | None = None  # provisioned hosts reducers multiplex over
    deadline_batches: int = 1  # heartbeat deadline for the failure detector
    degrade_below: float = 0.5  # alive/provisioned below this -> repair+shrink
    min_hosts: int = 1  # fewer survivors than this -> recovery exhausted
    verify: bool = True  # re-join recovered state vs the window fingerprint
    hosts_per_pod: int = 256  # pod granularity for plan_mesh_shape

    def __post_init__(self):
        if self.n_hosts is not None and self.n_hosts < 1:
            raise ValueError("n_hosts must be >= 1")
        if self.deadline_batches < 1:
            raise ValueError("deadline_batches must be >= 1")
        if not 0.0 <= self.degrade_below <= 1.0:
            raise ValueError("degrade_below must be in [0, 1]")
        if self.min_hosts < 1:
            raise ValueError("min_hosts must be >= 1")

    @property
    def enabled(self) -> bool:
        return self.n_hosts is not None


@dataclasses.dataclass(frozen=True)
class RecoveryReport:
    """Telemetry for one recovery event (``engine.recoveries``)."""

    batch: int  # batch boundary the recovery ran at
    lost_hosts: tuple[int, ...]
    lost_reducers: int  # logical reducers whose state was unreachable
    mode: str  # "replay" (same plan) | "degrade" (repaired plan)
    survivors: int  # hosts alive after the loss
    batches_replayed: int  # retained batches walked by lineage replay
    replayed_tuples: int  # emissions re-scattered into lost bins
    lost_share_tuples: int  # the lost reducers' retained-window share
    #                         (replayed_tuples <= this, by construction)
    migrated_tuples: int  # degrade mode: emissions re-routed by the repair
    reducers_before: int  # plan.total_reducers before / after recovery
    reducers_after: int
    verified: bool  # recovered state re-joined == window fingerprint
    tenant: str = ""  # multi-tenant runs: which query this event repaired
    #                   ("" in single-tenant engines; MultiQueryEngine
    #                   relabels per-query events it aggregates)


def record_recovery(obs, report: RecoveryReport) -> None:
    """Fold one recovery event into the observability registry
    (DESIGN.md §10).  The facade injects the tenant label for tenant
    engines; ``NULL_OBS`` makes every call here free when metrics are off.
    Counter taxonomy: events by mode (replay vs degrade), lineage volume
    (replayed tuples vs the lost share they reconstruct), repair-migration
    volume, and a loud counter for failed verifications — which also raise,
    but a scrape must see them after the process survives."""
    if obs.tracer.enabled:
        obs.instant(
            "recovery.report", cat="recovery", args=dataclasses.asdict(report)
        )
    if not obs.metrics.enabled:
        return
    obs.counter("stream_recovery_total", mode=report.mode).inc()
    obs.counter("stream_recovery_lost_reducers_total").inc(report.lost_reducers)
    obs.counter("stream_recovery_replayed_tuples_total").inc(
        report.replayed_tuples
    )
    obs.counter("stream_recovery_lost_share_tuples_total").inc(
        report.lost_share_tuples
    )
    if report.migrated_tuples:
        obs.counter("stream_recovery_migrated_tuples_total").inc(
            report.migrated_tuples
        )
    if not report.verified:
        obs.counter("stream_recovery_verify_failures_total").inc()
    obs.gauge("stream_hosts_alive").set(report.survivors)


class HostTracker:
    """Placement + liveness bookkeeping for the simulated reducer hosts.

    Logical reducer ids are the unit of state (bins are indexed by them);
    hosts are where they live.  Assignment is contiguous blocks over the
    alive list, so host loss takes out a contiguous slab of reducer ids
    and every surviving reducer's state stays in place.  A host can be:
    alive (heartbeating), *silenced* (fault fired, heartbeats stopped,
    not yet declared — the detection gap), declared lost (out of the
    pool), or fenced-awaiting-heal (partition: rejoins empty later).
    """

    def __init__(self, policy: RecoveryPolicy):
        if not policy.enabled:
            raise ValueError("HostTracker requires RecoveryPolicy.n_hosts")
        self.policy = policy
        self.provisioned = int(policy.n_hosts)
        self.alive: list[int] = list(range(self.provisioned))
        # host -> heal-at batch (None = permanent loss), set when a fault
        # fires; the host stays in ``alive`` until the detector declares it
        self.silenced: dict[int, int | None] = {}
        # declared-lost partitions waiting to heal: host -> heal-at batch
        self.fenced: dict[int, int] = {}
        self.host_of: np.ndarray = np.zeros(0, dtype=np.int64)

    # ---- placement ---------------------------------------------------------
    def assign(self, total_reducers: int) -> None:
        """(Re)place all reducers in contiguous blocks over alive hosts —
        called at every plan install, mirroring the full state rebuild."""
        n = max(1, len(self.alive))
        self.host_of = np.array(
            [self.alive[(r * n) // max(1, total_reducers)]
             for r in range(total_reducers)],
            dtype=np.int64,
        )

    def reducers_on(self, hosts) -> np.ndarray:
        """Logical reducer ids currently placed on the given hosts."""
        hosts = np.asarray(list(hosts), dtype=np.int64)
        if self.host_of.size == 0 or hosts.size == 0:
            return np.zeros(0, dtype=np.int64)
        return np.flatnonzero(np.isin(self.host_of, hosts)).astype(np.int64)

    def reassign(self, lost: np.ndarray) -> None:
        """Spread the lost reducers round-robin over the surviving hosts
        (same-plan repair: only the lost ids move; survivors stay put)."""
        lost = np.asarray(lost, dtype=np.int64)
        if lost.size and self.alive:
            surv = np.asarray(self.alive, dtype=np.int64)
            self.host_of[lost] = surv[np.arange(lost.size) % surv.size]

    # ---- liveness ----------------------------------------------------------
    def silence(self, host: int, heal_at: int | None = None) -> None:
        """A fault fired on ``host``: its heartbeats stop (permanently for
        ``host_loss``, until ``heal_at`` for ``partition``)."""
        if host in self.alive:
            self.silenced[host] = heal_at

    def beating(self) -> list[int]:
        return [h for h in self.alive if h not in self.silenced]

    def declare_lost(self, hosts) -> None:
        """The detector declared these hosts dead: out of the pool.  A
        silenced-by-partition host is fenced — its state is stale (the
        pool recovered without it) and is discarded when it heals."""
        for h in hosts:
            if h not in self.alive:
                continue
            self.alive.remove(h)
            heal_at = self.silenced.pop(h, None)
            if heal_at is not None:
                self.fenced[h] = heal_at

    def heal_due(self, batch: int) -> list[int]:
        """Fenced hosts whose partition healed by ``batch``: they rejoin
        the pool as empty spares (their pre-partition state was fenced
        off; reducers land on them again at the next plan install)."""
        healed = sorted(h for h, at in self.fenced.items() if at <= batch)
        for h in healed:
            self.fenced.pop(h)
            self.alive.append(h)
        self.alive.sort()
        return healed

    # ---- checkpoint --------------------------------------------------------
    def state_dict(self) -> dict[str, np.ndarray]:
        sil = sorted(self.silenced.items())
        return {
            "alive": np.asarray(self.alive, dtype=np.int64),
            "silenced": np.asarray(
                [(h, -1 if at is None else at) for h, at in sil],
                dtype=np.int64,
            ).reshape(-1, 2),
            "fenced": np.asarray(
                sorted(self.fenced.items()), dtype=np.int64
            ).reshape(-1, 2),
            "host_of": self.host_of,
        }

    def load_state_dict(self, state) -> None:
        self.alive = [int(h) for h in np.asarray(state["alive"])]
        self.silenced = {
            int(h): (None if at < 0 else int(at))
            for h, at in np.asarray(state["silenced"]).reshape(-1, 2)
        }
        self.fenced = {
            int(h): int(at)
            for h, at in np.asarray(state["fenced"]).reshape(-1, 2)
        }
        self.host_of = np.asarray(state["host_of"]).astype(np.int64)
