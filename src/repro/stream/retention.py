"""Windowed/TTL retention for carried streaming state (DESIGN.md §8).

The engine's carried reducer state is append-only under DESIGN.md §6's
semantics: every batch ever ingested stays resident so that future tuples
can join with it.  That is the demo simplification — an engine serving an
unbounded stream must *forget*.  Retention bounds carried state to a
sliding suffix of the stream (the retained **window**) defined by batch
count and/or wall-clock TTL, and changes the join semantics accordingly:
a new tuple joins only with retained partners.

Two fingerprints then coexist (both exact, both mod 2^32):

  * the **cumulative** fingerprint — every result the engine ever emitted
    (expiry never un-emits results already produced);
  * the **window** fingerprint — the join of the retained suffix alone,
    maintained incrementally by *retracting* each expiring batch's
    contribution: join(S ∪ E) − join(S) telescopes exactly like the
    insertion delta (term i = A_1..A_{i-1} ⋈ E_i ⋈ S_{i+1}..S_n with
    A = current state, E = expiring batch, S = survivors), and counts /
    orderless checksums subtract associatively mod 2^32.  The window
    fingerprint is what ``recompute_distributed(window=True)`` replays.

Expiry itself is pure host-side compute over state the tuples already
occupy — **no shuffle**: per-reducer bins are in batch-arrival order
(appends scatter at occupancy offsets, and replan rebuilds preserve row
order), so an expiring batch's emissions are exactly a *prefix* of every
reducer's bin and removal is a left shift (``remove_prefix``).  Bin
capacity is deliberately NOT shrunk here; compaction to tight capacity
rides the existing replan-migration rebuild, so retention adds no new
re-route of history.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

State = tuple[np.ndarray, np.ndarray, np.ndarray]  # (bins, valid, occup)


@dataclasses.dataclass(frozen=True)
class RetentionPolicy:
    """When does a retained batch expire?

    ``window_batches`` — keep at most the last W batches (None = unbounded).
    ``ttl_seconds``    — expire batches older than this on the engine's
                         clock (None = no TTL).  The engine's injectable
                         ``clock`` makes TTL deterministic under test.
    A batch expires when EITHER bound says so; both None (the default)
    reproduces the unbounded §6 baseline exactly.
    """

    window_batches: int | None = None
    ttl_seconds: float | None = None

    def __post_init__(self):
        if self.window_batches is not None and self.window_batches < 1:
            raise ValueError("window_batches must be >= 1")
        if self.ttl_seconds is not None and self.ttl_seconds <= 0:
            raise ValueError("ttl_seconds must be > 0")

    @property
    def enabled(self) -> bool:
        return self.window_batches is not None or self.ttl_seconds is not None

    def expired_prefix(
        self,
        retained_ids: Sequence[int],
        retained_ts: Sequence[float],
        next_batch_id: int,
        now: float,
    ) -> int:
        """How many of the oldest retained batches must expire *before*
        batch ``next_batch_id`` is ingested (so the window holds at most
        ``window_batches`` batches afterwards, all within TTL)."""
        n = len(retained_ids)
        drop = 0
        for i in range(n):
            out_of_window = (
                self.window_batches is not None
                and retained_ids[i] <= next_batch_id - self.window_batches
            )
            out_of_ttl = (
                self.ttl_seconds is not None
                and now - retained_ts[i] > self.ttl_seconds
            )
            if out_of_window or out_of_ttl:
                drop = i + 1
        return drop


def remove_prefix(state: State, counts: np.ndarray) -> State:
    """Drop the oldest ``counts[r]`` entries from the front of each reducer
    bin — the expiring batch's emissions, which sit at the head of every
    bin because appends are in batch-arrival order.  O(state) memmove,
    capacity unchanged (compaction happens at replan rebuild)."""
    bins, valid, occup = state
    counts = np.asarray(counts, dtype=occup.dtype)
    if counts.size == 0 or not counts.any():
        return state
    if np.any(counts > occup):
        raise ValueError("expiring more tuples than a reducer holds")
    k, cap = valid.shape
    new_occup = occup - counts
    # gather each bin shifted left by its own count; positions past the new
    # occupancy are cleared (the clip only touches already-masked slots)
    idx = np.minimum(np.arange(cap)[None, :] + counts[:, None], cap - 1)
    new_bins = np.take_along_axis(bins, idx[:, :, None], axis=1)
    new_valid = np.arange(cap)[None, :] < new_occup[:, None]
    new_bins[~new_valid] = 0
    return new_bins, new_valid, new_occup.astype(occup.dtype)


def lost_occupancy(states: dict[str, State], lost: np.ndarray) -> int:
    """The lost reducers' retained-window share: total emissions their
    bins held across all relations.  Lineage replay must reconstruct
    exactly this many tuples — the acceptance bound that distinguishes
    replay from a full-stream restore (DESIGN.md §5)."""
    lost = np.asarray(lost, dtype=np.int64)
    total = 0
    for _, _, occup in states.values():
        if occup.size and lost.size:
            total += int(occup[lost].sum())
    return total


def zero_reducers(state: State, lost: np.ndarray) -> State:
    """Clear the lost reducers' bins — the state-side materialization of a
    host loss (their carried tuples are unreachable).  Lineage replay then
    refills exactly these rows batch-by-batch; because appends scatter in
    batch-arrival order, the refilled bins are bit-identical to the bins
    the dead host carried."""
    lost = np.asarray(lost, dtype=np.int64)
    bins, valid, occup = state
    if lost.size == 0:
        return state
    bins, valid, occup = bins.copy(), valid.copy(), occup.copy()
    bins[lost] = 0
    valid[lost] = False
    occup[lost] = 0
    return bins, valid, occup


def select_reducers(
    dest: np.ndarray, lost: np.ndarray
) -> np.ndarray:
    """Boolean mask over one routed batch's emissions selecting those
    destined for the lost reducers — the per-batch lineage slice replay
    re-scatters."""
    if dest.size == 0 or np.asarray(lost).size == 0:
        return np.zeros(dest.shape, dtype=bool)
    return np.isin(dest, np.asarray(lost, dtype=dest.dtype))


def carried_tuples(states: dict[str, State]) -> tuple[int, int]:
    """(total retained emissions, worst per-reducer occupancy) across all
    relations — the soak metric that must stay flat under retention."""
    total, worst = 0, 0
    for _, _, occup in states.values():
        if occup.size:
            total += int(occup.sum())
            worst = max(worst, int(occup.max()))
    return total, worst
