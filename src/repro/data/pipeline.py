"""Deterministic sharded token pipeline with checkpointable state.

A synthetic corpus (seeded, reproducible) stands in for real shards: each
host generates only its shard's tokens (index-based, no coordination), and
the pipeline's position is one integer — saved inside the checkpoint, so a
restore resumes mid-epoch exactly.  Over-decomposition + a prefetch thread
gives host-level straggler tolerance: batches are produced ahead of
consumption and a slow generator never stalls the step loop until the
buffer drains.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class TokenPipeline:
    def __init__(
        self,
        vocab: int,
        batch: int,
        seq: int,
        seed: int = 0,
        shard: int = 0,
        num_shards: int = 1,
        prefetch: int = 2,
    ):
        if batch % num_shards:
            raise ValueError("global batch must divide num_shards")
        self.vocab = vocab
        self.batch = batch // num_shards
        self.seq = seq
        self.seed = seed
        self.shard = shard
        self.num_shards = num_shards
        self.step = 0
        self._prefetch = prefetch
        self._q: queue.Queue | None = None
        self._thread: threading.Thread | None = None

    # ---- deterministic access by index (seekable -> checkpointable) -------
    def batch_at(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.shard
        )
        # mildly Zipfian token stream (realistic vocab skew for the
        # embedding-gather analysis)
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        return ((z - 1) % self.vocab).astype(np.int32)

    def next_batch(self) -> np.ndarray:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    # ---- prefetching -------------------------------------------------------
    def start(self) -> None:
        self._q = queue.Queue(maxsize=self._prefetch)
        self._stop = False

        def work():
            s = self.step
            while not self._stop:
                try:
                    self._q.put((s, self.batch_at(s)), timeout=0.1)
                    s += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def next_prefetched(self) -> np.ndarray:
        assert self._q is not None, "call start() first"
        s, b = self._q.get()
        self.step = s + 1
        return b

    def stop(self) -> None:
        self._stop = True
        if self._thread:
            self._thread.join(timeout=2)
            self._thread = None

    # ---- checkpoint hooks ---------------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed, "shard": self.shard}

    def load_state_dict(self, state: dict) -> None:
        assert state["seed"] == self.seed and state["shard"] == self.shard
        self.step = int(state["step"])
