"""Data substrate: synthetic relations (paper workloads) + LM token pipeline."""
from .relations import (
    paper_2way,
    paper_3way,
    random_join_data,
    skewed_column,
    uniform_relation,
    zipf_column,
)

__all__ = [
    "paper_2way",
    "paper_3way",
    "random_join_data",
    "skewed_column",
    "uniform_relation",
    "zipf_column",
]
