"""Synthetic relation generators with controllable skew (paper §9 workloads).

Relations are columnar int64 arrays ``[N, arity]``.  ``zipf_relation``
produces a Zipf-distributed join column; ``paper_2way``/``paper_3way``
reproduce the experimental setups of §9.1/§9.2 (scaled by a factor so CPU
tests stay fast).
"""
from __future__ import annotations

import numpy as np

from repro.core.schema import JoinQuery


def uniform_relation(
    rng: np.random.Generator, n: int, arity: int, domain: int
) -> np.ndarray:
    return rng.integers(0, domain, size=(n, arity), dtype=np.int64)


def skewed_column(
    rng: np.random.Generator,
    n: int,
    domain: int,
    hh_values: list[int],
    hh_fraction: float,
) -> np.ndarray:
    """A column where ``hh_fraction`` of entries are drawn uniformly from
    ``hh_values`` and the rest uniformly from the remaining domain."""
    col = rng.integers(0, domain, size=n, dtype=np.int64)
    # keep ordinary values clear of the HHs
    for v in hh_values:
        col[col == v] = (v + 1 + rng.integers(0, domain - 1)) % domain
        col[col == v] = (v + 7) % domain if domain > 7 else (v + 1) % domain
    n_hh = int(n * hh_fraction)
    if n_hh and hh_values:
        idx = rng.choice(n, size=n_hh, replace=False)
        col[idx] = rng.choice(np.asarray(hh_values, dtype=np.int64), size=n_hh)
    return col


def zipf_column(rng: np.random.Generator, n: int, domain: int, a: float = 1.5) -> np.ndarray:
    """Zipf(a) column folded into [0, domain)."""
    return (rng.zipf(a, size=n) - 1).astype(np.int64) % domain


def paper_2way(
    rng: np.random.Generator,
    n_r: int = 20_000,
    n_s: int = 2_000,
    domain: int = 100_000,
    hh_value: int = 7,
    hh_fraction: float = 0.10,
) -> dict[str, np.ndarray]:
    """§9.1: R(A,B) ⋈ S(B,C); |R| = 10 * |S|; one HH in B at 10% of tuples.

    Defaults are the paper's 10^6 / 10^5 setup scaled by 50x for CPU tests.
    """
    b_r = skewed_column(rng, n_r, domain, [hh_value], hh_fraction)
    b_s = skewed_column(rng, n_s, domain, [hh_value], hh_fraction)
    r = np.stack([rng.integers(0, domain, n_r, dtype=np.int64), b_r], axis=1)
    s = np.stack([b_s, rng.integers(0, domain, n_s, dtype=np.int64)], axis=1)
    return {"R": r, "S": s}


def paper_3way(
    rng: np.random.Generator,
    n: int = 4_000,
    domain: int = 50_000,
    hh_b: tuple[int, int] = (11, 13),
    hh_c: tuple[int, ...] = (17,),
    hh_fraction: float = 0.10,
) -> dict[str, np.ndarray]:
    """§9.2: R(A,B) ⋈ S(B,E,C) ⋈ T(C,D); each relation 10^5 tuples (scaled);
    B has two HHs, C one; HHs account for ~10% of the input."""
    b_r = skewed_column(rng, n, domain, list(hh_b), hh_fraction)
    b_s = skewed_column(rng, n, domain, list(hh_b), hh_fraction)
    c_s = skewed_column(rng, n, domain, list(hh_c), hh_fraction)
    c_t = skewed_column(rng, n, domain, list(hh_c), hh_fraction)
    r = np.stack([rng.integers(0, domain, n, dtype=np.int64), b_r], axis=1)
    s = np.stack([b_s, rng.integers(0, domain, n, dtype=np.int64), c_s], axis=1)
    t = np.stack([c_t, rng.integers(0, domain, n, dtype=np.int64)], axis=1)
    return {"R": r, "S": s, "T": t}


def random_join_data(
    rng: np.random.Generator,
    query: JoinQuery,
    n_per_relation: int,
    domain: int,
    skew_attr: str | None = None,
    hh_values: list[int] | None = None,
    hh_fraction: float = 0.0,
) -> dict[str, np.ndarray]:
    """Generic generator for any JoinQuery: shared attrs share a domain so
    joins are non-trivially selective; optional skew on one attribute."""
    data = {}
    for rel in query.relations:
        cols = []
        for attr in rel.attrs:
            if attr == skew_attr and hh_values:
                cols.append(
                    skewed_column(rng, n_per_relation, domain, hh_values, hh_fraction)
                )
            else:
                cols.append(rng.integers(0, domain, n_per_relation, dtype=np.int64))
        data[rel.name] = np.stack(cols, axis=1)
    return data
