"""Uniform model API over the assigned architecture families.

``build_model(cfg)`` dispatches on ``cfg.family`` and returns a ``ModelApi``
whose members all share the same signatures, so the training loop, serving
loop and dry-run treat every architecture identically.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

from repro.configs.base import ArchConfig

from . import mamba2, moe, rwkv6, transformer


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ArchConfig
    init_params: Callable[[Any], dict]
    loss_fn: Callable[..., jnp.ndarray]  # (params, batch, **kw) -> scalar
    init_cache: Callable[..., dict] | None  # (batch, max_seq) -> cache
    decode_step: Callable[..., tuple] | None  # (params, cache, tokens, pos)
    forward_hidden: Callable[..., Any]


def build_model(cfg: ArchConfig) -> ModelApi:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return ModelApi(
            cfg=cfg,
            init_params=lambda key: transformer.init_params(cfg, key),
            loss_fn=lambda params, batch, **kw: transformer.loss_fn(cfg, params, batch, **kw),
            init_cache=lambda batch, max_seq, dtype=jnp.bfloat16: transformer.init_kv_cache(cfg, batch, max_seq, dtype),
            decode_step=lambda params, cache, tokens, pos, **kw: transformer.decode_step(cfg, params, cache, tokens, pos, **kw),
            forward_hidden=lambda params, batch, **kw: transformer.forward_hidden(
                cfg, params, batch.get("tokens"), batch.get("prefix_embeds"), **kw
            ),
        )
    if fam == "audio":  # encoder-only
        return ModelApi(
            cfg=cfg,
            init_params=lambda key: transformer.init_params(cfg, key),
            loss_fn=lambda params, batch, **kw: transformer.loss_fn(cfg, params, batch, **kw),
            init_cache=None,
            decode_step=None,
            forward_hidden=lambda params, batch, **kw: transformer.forward_hidden(
                cfg, params, batch.get("tokens"), batch.get("prefix_embeds"), **kw
            ),
        )
    if fam == "moe":
        return ModelApi(
            cfg=cfg,
            init_params=lambda key: moe.init_params(cfg, key),
            loss_fn=lambda params, batch, **kw: moe.loss_fn(cfg, params, batch, **kw),
            init_cache=lambda batch, max_seq, dtype=jnp.bfloat16: moe.init_kv_cache(cfg, batch, max_seq, dtype),
            decode_step=lambda params, cache, tokens, pos, **kw: moe.decode_step(cfg, params, cache, tokens, pos, **kw),
            forward_hidden=lambda params, batch, **kw: moe.forward_hidden(
                cfg, params, batch["tokens"], batch.get("prefix_embeds"), **kw
            ),
        )
    if fam == "ssm":
        return ModelApi(
            cfg=cfg,
            init_params=lambda key: rwkv6.init_params(cfg, key),
            loss_fn=lambda params, batch, **kw: rwkv6.loss_fn(cfg, params, batch, **kw),
            init_cache=lambda batch, max_seq=0, dtype=jnp.bfloat16: rwkv6.init_state(cfg, batch, dtype),
            decode_step=lambda params, cache, tokens, pos=None, **kw: rwkv6.decode_step(cfg, params, cache, tokens, pos, **kw),
            forward_hidden=lambda params, batch, **kw: rwkv6.forward_hidden(
                cfg, params, batch["tokens"], **kw
            ),
        )
    if fam == "hybrid":
        return ModelApi(
            cfg=cfg,
            init_params=lambda key: mamba2.init_params(cfg, key),
            loss_fn=lambda params, batch, **kw: mamba2.loss_fn(cfg, params, batch, **kw),
            init_cache=lambda batch, max_seq, dtype=jnp.bfloat16: mamba2.init_state(cfg, batch, max_seq, dtype),
            decode_step=lambda params, cache, tokens, pos, **kw: mamba2.decode_step(cfg, params, cache, tokens, pos, **kw),
            forward_hidden=lambda params, batch, **kw: mamba2.forward_hidden(
                cfg, params, batch["tokens"], **kw
            ),
        )
    raise ValueError(f"unknown family {fam}")


def make_batch(cfg: ArchConfig, rng, batch: int, seq: int) -> dict:
    """Synthetic batch with the right modality for the arch (stub frontends
    provide precomputed frame/patch embeddings, per the brief)."""
    import numpy as np

    out: dict = {}
    if cfg.family == "audio":
        out["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(batch, seq, cfg.d_model)), dtype=jnp.bfloat16
        )
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(batch, seq)), dtype=jnp.int32
        )
        return out
    out["tokens"] = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(batch, seq)), dtype=jnp.int32
    )
    if cfg.family == "vlm":
        n_patch = min(64, max(8, seq // 4))
        out["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(batch, n_patch, cfg.d_model)), dtype=jnp.bfloat16
        )
    return out
