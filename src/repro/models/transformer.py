"""Dense decoder/encoder transformer (covers command-r-plus, gemma3, olmo,
granite, internvl2 backbone, hubert encoder).

Layers are stacked along a leading axis and executed with ``lax.scan`` (one
compact HLO block regardless of depth) and per-layer remat.  Gemma-style
5:1 local:global patterns are handled with a per-layer ``is_global`` flag
threaded through the scan (mask arithmetic, no branching).  VLM/audio
frontends are stubs: precomputed ``prefix_embeds`` are concatenated ahead of
the token embeddings (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

from .layers import (
    AttnConfig,
    apply_norm,
    attention,
    attention_decode,
    chunked_cross_entropy,
    embed,
    init_attention,
    init_embedding,
    init_linear,
    init_mlp,
    init_norm,
    mlp,
)


def attn_config(cfg: ArchConfig) -> AttnConfig:
    return AttnConfig(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv=cfg.n_kv,
        head_dim=cfg.hd,
        rope_theta=cfg.rope_theta,
        causal=cfg.causal,
        window=cfg.window or None,
        qk_norm=cfg.qk_norm,
        bias=cfg.attn_bias,
    )


def init_block(key, cfg: ArchConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_norm(cfg.norm, cfg.d_model),
        "attn": init_attention(k1, attn_config(cfg)),
        "ln2": init_norm(cfg.norm, cfg.d_model),
        "mlp": init_mlp(
            k2, cfg.d_model, cfg.d_ff,
            gated=cfg.family != "audio",  # hubert uses plain gelu FFN
            bias=cfg.attn_bias,
        ),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 3)
    blocks = [init_block(keys[i], cfg) for i in range(cfg.n_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    params = {
        "embed": init_embedding(keys[-1], cfg.vocab, cfg.d_model),
        "blocks": stacked,
        "final_norm": init_norm(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(keys[-2], cfg.d_model, cfg.vocab)
    return params


def _block_apply(cfg: ArchConfig, blk: dict, x: jnp.ndarray, is_global) -> jnp.ndarray:
    from .layers import constrain_activations

    x = constrain_activations(x)
    h = apply_norm(cfg.norm, blk["ln1"], x)
    x = x + attention(blk["attn"], attn_config(cfg), h, is_global)
    h = apply_norm(cfg.norm, blk["ln2"], x)
    x = x + mlp(blk["mlp"], h, cfg.act)
    return x


def _layer_flags(cfg: ArchConfig) -> jnp.ndarray:
    idx = jnp.arange(cfg.n_layers)
    if cfg.global_period:
        return (idx + 1) % cfg.global_period == 0
    return jnp.ones(cfg.n_layers, bool)


def forward_hidden(
    cfg: ArchConfig,
    params: dict,
    tokens: jnp.ndarray | None,  # [B, L]; None for pure-frontend (audio) input
    prefix_embeds: jnp.ndarray | None = None,  # [B, P, d] (vlm/audio stub)
    dtype=jnp.bfloat16,
    remat: bool = True,
) -> jnp.ndarray:
    """Token (+ prefix) embeddings -> final-norm hidden states [B, L*, d]."""
    if tokens is None:
        if prefix_embeds is None:
            raise ValueError("need tokens and/or prefix_embeds")
        x = prefix_embeds.astype(dtype)
    else:
        x = embed(params["embed"], tokens, dtype)
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(dtype), x], axis=1)
        prefix_embeds = None  # consumed

    body = partial(_block_apply, cfg)
    if remat:
        body = jax.checkpoint(body)

    def step(x, scanned):
        blk, is_global = scanned
        return body(blk, x, is_global), None

    x, _ = jax.lax.scan(step, x, (params["blocks"], _layer_flags(cfg)))
    return apply_norm(cfg.norm, params["final_norm"], x)


def logits_table(cfg: ArchConfig, params: dict) -> jnp.ndarray:
    """[V, d] readout table (tied embedding or untied head)."""
    if cfg.tie_embeddings:
        return params["embed"]["table"]
    return params["lm_head"]["w"].T


def loss_fn(
    cfg: ArchConfig,
    params: dict,
    batch: dict,
    dtype=jnp.bfloat16,
    remat: bool = True,
    loss_chunk: int = 512,
) -> jnp.ndarray:
    """Next-token (or frame-label for encoders) cross entropy."""
    tokens = batch.get("tokens")
    h = forward_hidden(
        cfg, params, tokens, batch.get("prefix_embeds"), dtype=dtype, remat=remat
    )
    if cfg.causal:
        prefix = h.shape[1] - tokens.shape[1]
        h_txt = h[:, prefix:, :]
        inputs = h_txt[:, :-1, :]
        labels = tokens[:, 1:]
    else:
        inputs, labels = h, batch["labels"]
    return chunked_cross_entropy(
        inputs, logits_table(cfg, params), labels, chunk=loss_chunk
    )


# ------------------------------------------------------------------ serving
def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> dict:
    shape = (cfg.n_layers, batch, cfg.n_kv, max_seq, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(
    cfg: ArchConfig,
    params: dict,
    cache: dict,
    tokens: jnp.ndarray,  # [B, 1]
    pos: jnp.ndarray,  # [] tokens already in cache
    dtype=jnp.bfloat16,
) -> tuple[jnp.ndarray, dict]:
    """One autoregressive step; returns (logits [B, V], new cache)."""
    x = embed(params["embed"], tokens, dtype)
    flags = _layer_flags(cfg)

    acfg = attn_config(cfg)

    def step(x, scanned):
        blk, is_global, kc, vc = scanned
        h = apply_norm(cfg.norm, blk["ln1"], x)
        y, kc, vc = attention_decode(blk["attn"], acfg, h, kc, vc, pos, is_global)
        x = x + y
        h = apply_norm(cfg.norm, blk["ln2"], x)
        x = x + mlp(blk["mlp"], h, cfg.act)
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        step, x, (params["blocks"], flags, cache["k"], cache["v"])
    )
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = (x[:, -1, :] @ logits_table(cfg, params).T.astype(x.dtype)).astype(
        jnp.float32
    )
    return logits, {"k": k_new, "v": v_new}


def prefill(
    cfg: ArchConfig,
    params: dict,
    tokens: jnp.ndarray,  # [B, L]
    cache: dict,
    dtype=jnp.bfloat16,
) -> tuple[jnp.ndarray, dict]:
    """Prefill the cache with a full prompt; returns (last-position logits,
    cache).  Implemented as full forward + cache write (inference-prefill)."""
    x = embed(params["embed"], tokens, dtype)
    flags = _layer_flags(cfg)
    acfg = attn_config(cfg)

    def step(x, scanned):
        blk, is_global = scanned
        h = apply_norm(cfg.norm, blk["ln1"], x)
        # recompute k/v to store in cache
        from .layers import _qkv, rotary_angles, apply_rotary

        q, k, v = _qkv(blk["attn"], acfg, h)
        cos, sin = rotary_angles(jnp.arange(h.shape[1]), acfg.head_dim, acfg.rope_theta)
        k_rot = apply_rotary(k, cos, sin)
        x = x + attention(blk["attn"], acfg, h, is_global)
        h2 = apply_norm(cfg.norm, blk["ln2"], x)
        x = x + mlp(blk["mlp"], h2, cfg.act)
        return x, (k_rot.astype(dtype), v.astype(dtype))

    x, (ks, vs) = jax.lax.scan(step, x, (params["blocks"], flags))
    l = tokens.shape[1]
    cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], ks, 0, axis=3),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vs, 0, axis=3),
    }
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = (x[:, -1, :] @ logits_table(cfg, params).T.astype(x.dtype)).astype(
        jnp.float32
    )
    return logits, cache
