"""Mixture-of-Experts transformer with SharesSkew expert dispatch.

The paper's technique transferred (DESIGN.md §2): token->expert routing is a
skewed 2-way join ``Tokens(t, e) ⋈ Experts(e, W_e)``.  Hot experts are the
heavy hitters; the Shares rectangle of Example 2 becomes a *replica grid*:
tokens headed to a hot expert are hash-partitioned across that expert's
replicas (the x dimension; the y dimension — splitting the expert weights —
is realized by the mesh's tensor-parallel sharding of expert matrices).

Dispatch is sort-based and static-shaped: slot count S = E + extra_slots and
per-slot capacity C are compile-time constants; *which* expert each extra
slot serves is a runtime value recomputed from the batch's expert histogram
(`plan_replica_slots`), so hot-expert relief needs no recompilation.  The
binning primitive is the same ``group_by_reducer`` that shuffles join
tuples — the MoE dispatch IS the join engine's shuffle.

The naive baseline (capacity-factor top-k with drops) is this same code with
``extra_slots=0``.
"""
from __future__ import annotations

import math
import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.mapreduce.hashing import mix32_jnp
from repro.mapreduce.local_join import group_by_reducer

from .layers import (
    apply_norm,
    attention,
    attention_decode,
    chunked_cross_entropy,
    embed,
    init_attention,
    init_embedding,
    init_linear,
    init_mlp,
    init_norm,
    mlp,
    _dense_init,
)
from .transformer import attn_config, logits_table, _layer_flags


# ----------------------------------------------------------------- init
def init_moe_block(key, cfg: ArchConfig) -> dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    e, d, fe = cfg.n_experts, cfg.d_model, cfg.d_expert
    blk = {
        "ln1": init_norm(cfg.norm, d),
        "attn": init_attention(k1, attn_config(cfg)),
        "ln2": init_norm(cfg.norm, d),
        "router": _dense_init(k2, (d, e)),
        "experts": {
            "w_gate": _dense_init(k3, (e, d, fe)),
            "w_up": _dense_init(k4, (e, d, fe)),
            "w_down": _dense_init(k5, (e, fe, d), scale=1.0 / math.sqrt(fe)),
        },
    }
    if cfg.n_shared:
        k6, k7 = jax.random.split(k1)
        blk["shared"] = init_mlp(k6, d, cfg.d_ff, gated=True)
        blk["shared_gate"] = _dense_init(k7, (d, 1))
    return blk


def init_params(cfg: ArchConfig, key) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 3)
    blocks = [init_moe_block(keys[i], cfg) for i in range(cfg.n_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    params = {
        "embed": init_embedding(keys[-1], cfg.vocab, cfg.d_model),
        "blocks": stacked,
        "final_norm": init_norm(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(keys[-2], cfg.d_model, cfg.vocab)
    return params


# ------------------------------------------------- SharesSkew replica plan
def plan_replica_slots(
    counts: jnp.ndarray,  # [E] tokens routed to each expert this batch
    capacity: int,
    n_experts: int,
    extra_slots: int,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Allocate ``extra_slots`` replica slots to overloaded experts.

    Returns (slot_expert [E+extra], replica_count [E], extra_base [E]).
    need_e = ceil(count_e / C) - 1 replicas beyond the primary; grants go to
    the neediest experts first (the heavy hitters), truncated to the budget —
    the reducer-allocation rule of paper §4.2 with q = capacity.
    """
    e = n_experts
    need = jnp.maximum((counts + capacity - 1) // capacity - 1, 0)
    order = jnp.argsort(-need)
    sorted_need = need[order]
    cum = jnp.cumsum(sorted_need)
    grant_sorted = jnp.clip(sorted_need - jnp.maximum(cum - extra_slots, 0), 0)
    grant = jnp.zeros(e, jnp.int32).at[order].set(grant_sorted.astype(jnp.int32))
    replica_count = 1 + grant
    extra_base = e + jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(grant)[:-1].astype(jnp.int32)]
    )
    slot_expert = jnp.concatenate(
        [
            jnp.arange(e, dtype=jnp.int32),
            jnp.repeat(
                jnp.arange(e, dtype=jnp.int32), grant, total_repeat_length=extra_slots
            ),
        ]
    )
    return slot_expert, replica_count, extra_base


# ------------------------------------------------------------- moe ffn
def moe_ffn(
    blk: dict,
    x: jnp.ndarray,  # [B, L, d]
    cfg: ArchConfig,
    capacity_factor: float = 1.25,
    extra_slots: int = 0,
    expert_pad: int = 0,
    return_stats: bool = False,
):
    """Group-local dispatch: one dispatch group per sequence, so the
    sort/bin/gather stays local to the data shard (a global argsort would
    force XLA to replicate it).  The [G, S, cap, d] dispatch buffer is the
    MoE all-to-all: G is batch-sharded, S is expert-sharded.  This mirrors
    how the join engine shards its shuffle (mapper-local binning, one
    exchange)."""
    b, l, d = x.shape
    g, tg = b, l  # dispatch groups = sequences
    e, k = cfg.n_experts, cfg.top_k
    s = e + extra_slots
    cap = max(8, int(math.ceil(tg * k * capacity_factor / s)))

    logits = (x @ blk["router"].astype(x.dtype)).astype(jnp.float32)  # [g,tg,E]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)  # [g, tg, k]
    topw = topw / topw.sum(-1, keepdims=True)

    flat_e = topi.reshape(g, tg * k).astype(jnp.int32)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)[None], (g, tg * k)
    )
    flat_c = jnp.broadcast_to(
        jnp.arange(tg * k, dtype=jnp.int32)[None], (g, tg * k)
    )

    # pad the expert dim so it tiles the "model" axis evenly (e.g. qwen2's
    # 60 experts -> 64): unpadded counts force XLA to all-gather the whole
    # dispatch tensor around every slot-dim reshard
    e_pad = max(e, expert_pad, int(os.environ.get("REPRO_EXPERT_PAD", "0")))

    if extra_slots > 0:
        # global expert histogram (tiny [E] reduction across shards)
        counts = jnp.zeros(e, jnp.int32).at[topi.reshape(-1)].add(1)
        slot_expert, replica_count, extra_base = plan_replica_slots(
            counts, cap * g, e, extra_slots
        )
        # SharesSkew map step: hash-partition tokens across replicas.
        # extra_base indexes extra slots from E; rebase to 0 for the
        # separate extra binning below.
        gid = jnp.arange(g, dtype=jnp.int32)[:, None] * (tg * k) + flat_c
        r = (
            mix32_jnp(gid, 0xD15C)
            % replica_count[flat_e].astype(jnp.uint32)
        ).astype(jnp.int32)
        dest_p = jnp.where(r == 0, flat_e, jnp.int32(-1))
        dest_x = jnp.where(r > 0, extra_base[flat_e] - e + r - 1, jnp.int32(-1))
        slot_expert_x = slot_expert[e:]
    else:
        dest_p = flat_e
        dest_x = None

    rows = jnp.stack([flat_t, flat_c], axis=-1)  # [g, tg*k, 2]
    w_flat = topw.reshape(g, tg * k)

    from .layers import constrain_moe_dispatch as _cmd

    def expert_mlp(xs, wg, wu, wd):  # [g, n, cap, d] x [n, d, f] -> [g, n, cap, d]
        xs = _cmd(xs)
        h = jax.nn.silu(jnp.einsum("gscd,sdf->gscf", xs, wg)) * jnp.einsum(
            "gscd,sdf->gscf", xs, wu
        )
        h = _cmd(h)
        return _cmd(jnp.einsum("gscf,sfd->gscd", h, wd))

    def dispatch_compute_combine(dest, n_slots, wg, wu, wd):
        """bin -> gather -> expert mlp -> weighted scatter-back."""
        bins, valid, loads, _ = jax.vmap(
            lambda dd, rr: group_by_reducer(dd, rr, n_slots, cap)
        )(dest, rows)
        tok = bins[..., 0]  # [g, n_slots, cap]
        choice = bins[..., 1]
        xa = jax.vmap(lambda xv, tv: xv[tv])(x, tok)
        xa = jnp.where(valid[..., None], xa, 0)
        y = expert_mlp(xa, wg, wu, wd)
        w_choice = jax.vmap(lambda wv, cv: wv[cv])(w_flat, choice).astype(y.dtype)
        scatter_to = jnp.where(valid, tok, tg)
        out = jax.vmap(
            lambda yv, tv, wv: jnp.zeros((tg + 1, d), yv.dtype)
            .at[tv]
            .add(yv * wv[..., None])[:tg]
        )(y, scatter_to, w_choice)
        return out, valid, loads

    w = blk["experts"]

    def padded(arr):  # [E, ...] -> [E_pad, ...]
        if e_pad == e:
            return arr
        return jnp.pad(arr, ((0, e_pad - e),) + ((0, 0),) * (arr.ndim - 1))

    # primary slots: expert dim intact -> pure expert parallelism (no
    # weight gather, E_pad tiles "model" evenly)
    out, valid_p, loads = dispatch_compute_combine(
        dest_p, e_pad,
        padded(w["w_gate"]).astype(x.dtype),
        padded(w["w_up"]).astype(x.dtype),
        padded(w["w_down"]).astype(x.dtype),
    )
    n_valid = valid_p.sum()
    if dest_x is not None:
        # replica slots: the SharesSkew hot-expert replicas — gather only
        # the few replicated experts' weights (the paper's "replicate the
        # small side"); binned separately so no sharded-dim slicing occurs.
        out_x, valid_x, loads_x = dispatch_compute_combine(
            dest_x, extra_slots,
            w["w_gate"][slot_expert_x].astype(x.dtype),
            w["w_up"][slot_expert_x].astype(x.dtype),
            w["w_down"][slot_expert_x].astype(x.dtype),
        )
        out = out + out_x
        n_valid = n_valid + valid_x.sum()
        loads = jnp.concatenate([loads, loads_x], axis=-1)

    if cfg.n_shared:
        gate = jax.nn.sigmoid(
            (x @ blk["shared_gate"].astype(x.dtype)).astype(jnp.float32)
        ).astype(x.dtype)
        out = out + gate * mlp(blk["shared"], x, cfg.act)

    # load-balance auxiliary loss (Switch-style)
    frac = jnp.zeros(e, jnp.float32).at[topi.reshape(-1)].add(1.0) / (g * tg * k)
    prob_mean = probs.reshape(-1, e).mean(0)
    aux = e * jnp.sum(frac * prob_mean)

    if return_stats:
        dropped = g * tg * k - n_valid
        stats = {
            "dropped": dropped,
            "drop_rate": dropped / (g * tg * k),
            "slot_loads": loads.sum(0),
            "aux_loss": aux,
        }
        return out, aux, stats
    return out, aux


# ------------------------------------------------------------- full model
def _block_apply(cfg, cap_factor, extra_slots, expert_pad, blk, x, is_global):
    from .layers import constrain_activations

    x = constrain_activations(x)
    h = apply_norm(cfg.norm, blk["ln1"], x)
    x = x + attention(blk["attn"], attn_config(cfg), h, is_global)
    h = apply_norm(cfg.norm, blk["ln2"], x)
    y, aux = moe_ffn(blk, h, cfg, cap_factor, extra_slots, expert_pad)
    return x + y, aux


def forward_hidden(
    cfg: ArchConfig,
    params: dict,
    tokens: jnp.ndarray,
    prefix_embeds=None,
    dtype=jnp.bfloat16,
    remat: bool = True,
    capacity_factor: float = 1.25,
    extra_slots: int = 0,
    expert_pad: int = 0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (hidden, mean aux loss)."""
    x = embed(params["embed"], tokens, dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(dtype), x], axis=1)
    body = partial(_block_apply, cfg, capacity_factor, extra_slots, expert_pad)
    if remat:
        body = jax.checkpoint(body)

    def step(x, scanned):
        blk, flag = scanned
        x, aux = body(blk, x, flag)
        return x, aux

    x, auxs = jax.lax.scan(step, x, (params["blocks"], _layer_flags(cfg)))
    return apply_norm(cfg.norm, params["final_norm"], x), auxs.mean()


def loss_fn(
    cfg: ArchConfig,
    params: dict,
    batch: dict,
    dtype=jnp.bfloat16,
    remat: bool = True,
    loss_chunk: int = 512,
    capacity_factor: float = 1.25,
    extra_slots: int = 0,
    expert_pad: int = 0,
    aux_coef: float = 0.01,
) -> jnp.ndarray:
    tokens = batch["tokens"]
    h, aux = forward_hidden(
        cfg, params, tokens, batch.get("prefix_embeds"),
        dtype=dtype, remat=remat,
        capacity_factor=capacity_factor, extra_slots=extra_slots,
        expert_pad=expert_pad,
    )
    ce = chunked_cross_entropy(
        h[:, :-1, :], logits_table(cfg, params), tokens[:, 1:], chunk=loss_chunk
    )
    return ce + aux_coef * aux


# ------------------------------------------------------------------ serving
def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> dict:
    shape = (cfg.n_layers, batch, cfg.n_kv, max_seq, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(
    cfg: ArchConfig,
    params: dict,
    cache: dict,
    tokens: jnp.ndarray,  # [B, 1]
    pos: jnp.ndarray,
    dtype=jnp.bfloat16,
    capacity_factor: float = 2.0,
    extra_slots: int = 0,
    expert_pad: int = 0,
) -> tuple[jnp.ndarray, dict]:
    x = embed(params["embed"], tokens, dtype)
    acfg = attn_config(cfg)

    def step(x, scanned):
        blk, flag, kc, vc = scanned
        h = apply_norm(cfg.norm, blk["ln1"], x)
        y, kc, vc = attention_decode(blk["attn"], acfg, h, kc, vc, pos, flag)
        x = x + y
        h = apply_norm(cfg.norm, blk["ln2"], x)
        y, _ = moe_ffn(blk, h, cfg, capacity_factor, extra_slots, expert_pad)
        return x + y, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        step, x, (params["blocks"], _layer_flags(cfg), cache["k"], cache["v"])
    )
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = (x[:, -1, :] @ logits_table(cfg, params).T.astype(x.dtype)).astype(jnp.float32)
    return logits, {"k": k_new, "v": v_new}
