"""Model zoo: dense / MoE / RWKV6 / Mamba2-hybrid / encoder / VLM backbones."""
from .zoo import ModelApi, build_model, make_batch

__all__ = ["ModelApi", "build_model", "make_batch"]
