"""Shared model layers: norms, attention (GQA / sliding-window), MLPs, rotary.

Pure-JAX (no flax): parameters are nested dicts of arrays; ``init_*``
functions build them, ``apply``-style functions consume them.  Compute dtype
is the caller's choice (params are cast on entry); accumulation-sensitive
ops (norms, softmax, losses) run in float32.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Callable

import jax
import jax.numpy as jnp

Params = dict

# When seq-len exceeds this, attention switches to the chunked (flash-style,
# scan-over-query-blocks) path so [L, L] score matrices never materialize.
# Env-overridable so perf sweeps (benchmarks/) can vary them per run.
ATTN_CHUNK_THRESHOLD = int(os.environ.get("REPRO_ATTN_CHUNK_THRESHOLD", "2048"))
ATTN_CHUNK = int(os.environ.get("REPRO_ATTN_CHUNK", "1024"))

# ------------------------------------------------------------------ sharding
# Activation-sharding constraint hook (sequence parallelism): the launcher
# sets a spec like P(("pod","data"), "model", None); models call
# ``constrain_activations`` on the residual stream at layer boundaries.
_ACT_SPEC: tuple | None = None  # (PartitionSpec, axis_sizes dict)


def set_activation_sharding(spec, axis_sizes: dict | None = None) -> None:
    global _ACT_SPEC
    _ACT_SPEC = None if spec is None else (spec, dict(axis_sizes or {}))


def _apply_spec(x: jnp.ndarray, spec, sizes: dict) -> jnp.ndarray:
    dims = []
    for d, s in zip(x.shape, spec):
        if s is None:
            dims.append(None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        total = 1
        for a in axes:
            total *= sizes.get(a, 1)
        dims.append(s if d % max(total, 1) == 0 else None)
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(x, P(*dims))


def constrain_activations(x: jnp.ndarray) -> jnp.ndarray:
    """Apply the configured [B, S, d] activation sharding if dims divide."""
    if _ACT_SPEC is None or x.ndim != 3:
        return x
    spec, sizes = _ACT_SPEC
    return _apply_spec(x, spec, sizes)


def constrain_moe_dispatch(x: jnp.ndarray) -> jnp.ndarray:
    """[g, slots, cap, d/f] MoE dispatch tensors: g over the data axes,
    slots over "model" — forces the 2-D (DP x EP) sharding of the expert
    einsum (XLA's propagation alone all-gathers the group dim)."""
    if _ACT_SPEC is None or x.ndim != 4:
        return x
    (spec, sizes) = _ACT_SPEC
    dp = spec[0]
    return _apply_spec(x, (dp, "model", None, None), sizes)


# --------------------------------------------------------------------- utils
def _dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 1 else 1
    if scale is None:
        scale = 1.0 / math.sqrt(fan_in)
    return jax.random.normal(key, shape, dtype) * scale


def linear(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def init_linear(key, d_in: int, d_out: int, bias: bool = False) -> Params:
    p = {"w": _dense_init(key, (d_in, d_out))}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


# --------------------------------------------------------------------- norms
def rms_norm(params: Params | None, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    if params is not None and "scale" in params:
        y = y * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def layer_norm(params: Params | None, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """LayerNorm; with params=None it is OLMo's non-parametric LN."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if params is not None:
        if "scale" in params:
            y = y * params["scale"].astype(jnp.float32)
        if "bias" in params:
            y = y + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def init_norm(kind: str, d: int) -> Params | None:
    if kind == "rms":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layer":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    if kind == "nonparametric":  # OLMo
        return None
    raise ValueError(kind)


def apply_norm(kind: str, params: Params | None, x: jnp.ndarray) -> jnp.ndarray:
    if kind == "rms":
        return rms_norm(params, x)
    return layer_norm(params, x)


# -------------------------------------------------------------------- rotary
def rotary_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., L, half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: [B, H, L, D]; cos/sin: [L, D/2] (or broadcastable)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos.astype(x.dtype)
    s = sin.astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ----------------------------------------------------------------- attention
@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    rope_theta: float = 1e4
    causal: bool = True
    window: int | None = None  # sliding-window size (None = full)
    qk_norm: bool = False
    bias: bool = False
    logit_softcap: float | None = None


def init_attention(key, cfg: AttnConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    p = {
        "wq": _dense_init(k1, (d, cfg.n_heads * hd)),
        "wk": _dense_init(k2, (d, cfg.n_kv * hd)),
        "wv": _dense_init(k3, (d, cfg.n_kv * hd)),
        "wo": _dense_init(k4, (cfg.n_heads * hd, d)),
    }
    if cfg.bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((hd,), jnp.float32)}
    return p


def _qkv(params: Params, cfg: AttnConfig, x: jnp.ndarray):
    b, l, _ = x.shape
    hd = cfg.head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, l, cfg.n_heads, hd)
    k = (x @ params["wk"].astype(x.dtype)).reshape(b, l, cfg.n_kv, hd)
    v = (x @ params["wv"].astype(x.dtype)).reshape(b, l, cfg.n_kv, hd)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype).reshape(cfg.n_heads, hd)
        k = k + params["bk"].astype(x.dtype).reshape(cfg.n_kv, hd)
        v = v + params["bv"].astype(x.dtype).reshape(cfg.n_kv, hd)
    if cfg.qk_norm:
        q = rms_norm(params["q_norm"], q)
        k = rms_norm(params["k_norm"], k)
    return (
        q.transpose(0, 2, 1, 3),  # [B, H, L, D]
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
    )


def _sdpa(
    q: jnp.ndarray,  # [B, H, Lq, D]
    k: jnp.ndarray,  # [B, Hkv, Lk, D]
    v: jnp.ndarray,
    causal: bool,
    window: int | None,
    q_offset: int | jnp.ndarray = 0,
    softcap: float | None = None,
) -> jnp.ndarray:
    b, h, lq, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    qg = q.reshape(b, hkv, group, lq, d)
    s = jnp.einsum(
        "bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(d)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    lk = k.shape[2]
    q_pos = jnp.arange(lq) + q_offset  # absolute positions of queries
    k_pos = jnp.arange(lk)
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, lq, d).astype(q.dtype)


def _sdpa_chunked(
    q: jnp.ndarray,  # [B, H, L, D]
    k: jnp.ndarray,  # [B, Hkv, L, D]
    v: jnp.ndarray,
    causal: bool,
    eff_window: jnp.ndarray | None,  # traced key-range bound or None
    chunk: int,
    softcap: float | None,
) -> jnp.ndarray:
    """Scan over query blocks (flash-style): peak score memory is
    [B, H, chunk, L] instead of [B, H, L, L].  Each chunk body is
    checkpointed so the backward pass re-materializes scores per chunk."""
    b, h, l, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    n = l // chunk
    qg = q.reshape(b, hkv, group, n, chunk, d)
    qg = jnp.moveaxis(qg, 3, 0)  # [n, B, hkv, g, chunk, D]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    k_pos = jnp.arange(l)
    scale = 1.0 / math.sqrt(d)

    @jax.checkpoint
    def body(_, xs):
        qc, i = xs  # [B, hkv, g, chunk, D], []
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qc.astype(jnp.float32), kf) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = i * chunk + jnp.arange(chunk)
        mask = jnp.ones((chunk, l), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if eff_window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < eff_window
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
        return None, o.astype(q.dtype)

    _, out = jax.lax.scan(body, None, (qg, jnp.arange(n)))
    out = jnp.moveaxis(out, 0, 3)  # [B, hkv, g, n, chunk, D]
    return out.reshape(b, h, l, d)


def attention(
    params: Params,
    cfg: AttnConfig,
    x: jnp.ndarray,  # [B, L, d_model]
    is_global: bool | jnp.ndarray = True,
) -> jnp.ndarray:
    """Full attention; ``is_global=False`` applies cfg.window (Gemma-style
    local layers).  ``is_global`` may be a traced bool so scanned layer
    stacks can alternate local/global without branching.  Long sequences
    take the chunked path (no [L, L] materialization)."""
    b, l, _ = x.shape
    q, k, v = _qkv(params, cfg, x)
    cos, sin = rotary_angles(jnp.arange(l), cfg.head_dim, cfg.rope_theta)
    q = apply_rotary(q, cos, sin)
    k = apply_rotary(k, cos, sin)

    eff_window = None
    if cfg.window is not None:
        eff_window = jnp.where(is_global, jnp.int32(l), jnp.int32(cfg.window))

    # opt-in Pallas flash-attention path (TPU target; interpret mode on CPU).
    # Full-window causal/bidir only — local layers keep the masked jnp path.
    if (
        os.environ.get("REPRO_USE_FLASH") == "1"
        and cfg.window is None
        and cfg.logit_softcap is None
        and l % 128 == 0
    ):
        from repro.kernels.flash_attention import flash_attention_pallas

        out = flash_attention_pallas(q, k, v, causal=cfg.causal)
    elif l > ATTN_CHUNK_THRESHOLD and l % ATTN_CHUNK == 0:
        out = _sdpa_chunked(
            q, k, v, cfg.causal, eff_window, ATTN_CHUNK, cfg.logit_softcap
        )
    elif eff_window is None:
        out = _sdpa(q, k, v, cfg.causal, None, softcap=cfg.logit_softcap)
    else:
        hkv, group = cfg.n_kv, cfg.n_heads // cfg.n_kv
        qg = q.reshape(b, hkv, group, l, cfg.head_dim)
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)
        ) / math.sqrt(cfg.head_dim)
        if cfg.logit_softcap:
            s = cfg.logit_softcap * jnp.tanh(s / cfg.logit_softcap)
        q_pos = jnp.arange(l)
        k_pos = jnp.arange(l)
        mask = q_pos[:, None] >= k_pos[None, :]
        mask &= (q_pos[:, None] - k_pos[None, :]) < eff_window
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
        out = out.reshape(b, hkv * group, l, cfg.head_dim).astype(x.dtype)
    y = out.transpose(0, 2, 1, 3).reshape(b, l, cfg.n_heads * cfg.head_dim)
    return y @ params["wo"].astype(x.dtype)


def attention_decode(
    params: Params,
    cfg: AttnConfig,
    x: jnp.ndarray,  # [B, 1, d_model] — one new token
    k_cache: jnp.ndarray,  # [B, Hkv, S, D]
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,  # [] current position (number of tokens already cached)
    is_global: bool | jnp.ndarray = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode step against a KV cache. Returns (y, k_cache, v_cache).
    ``is_global`` lifts the sliding window for Gemma-style global layers."""
    b = x.shape[0]
    q, k, v = _qkv(params, cfg, x)  # q [B,H,1,D], k/v [B,Hkv,1,D]
    cos, sin = rotary_angles(pos[None], cfg.head_dim, cfg.rope_theta)
    q = apply_rotary(q, cos, sin)
    k = apply_rotary(k, cos, sin)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), pos, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), pos, axis=2)
    s_max = k_cache.shape[2]
    hkv, group = cfg.n_kv, cfg.n_heads // cfg.n_kv
    qg = q.reshape(b, hkv, group, 1, cfg.head_dim)
    s = jnp.einsum(
        "bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) / math.sqrt(cfg.head_dim)
    if cfg.logit_softcap:
        s = cfg.logit_softcap * jnp.tanh(s / cfg.logit_softcap)
    k_pos = jnp.arange(s_max)
    valid = k_pos[None, :] <= pos
    if cfg.window is not None:
        in_window = (pos - k_pos[None, :]) < cfg.window
        valid &= in_window | jnp.asarray(is_global)
    s = jnp.where(valid[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v_cache.astype(jnp.float32))
    out = out.reshape(b, cfg.n_heads, 1, cfg.head_dim).astype(x.dtype)
    y = out.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.head_dim)
    return y @ params["wo"].astype(x.dtype), k_cache, v_cache


# ---------------------------------------------------------------------- MLPs
def init_mlp(key, d: int, f: int, gated: bool = True, bias: bool = False) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_up": _dense_init(ks[0], (d, f)), "w_down": _dense_init(ks[1], (f, d))}
    if gated:
        p["w_gate"] = _dense_init(ks[2], (d, f))
    if bias:
        p["b_up"] = jnp.zeros((f,), jnp.float32)
        p["b_down"] = jnp.zeros((d,), jnp.float32)
    return p


def mlp(params: Params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    a = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
         "gelu_tanh": lambda u: jax.nn.gelu(u, approximate=True)}[act]
    up = x @ params["w_up"].astype(x.dtype)
    if "b_up" in params:
        up = up + params["b_up"].astype(x.dtype)
    if "w_gate" in params:
        h = a(x @ params["w_gate"].astype(x.dtype)) * up
    else:
        h = a(up)
    y = h @ params["w_down"].astype(x.dtype)
    if "b_down" in params:
        y = y + params["b_down"].astype(x.dtype)
    return y


# ----------------------------------------------------------------- embedding
def init_embedding(key, vocab: int, d: int) -> Params:
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed(params: Params, tokens: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    return params["table"].astype(dtype)[tokens]


def chunked_cross_entropy(
    x: jnp.ndarray,  # [B, L, d] final hidden states
    emb_table: jnp.ndarray,  # [V, d] (tied) or lm_head [d, V] passed transposed
    labels: jnp.ndarray,  # [B, L]
    chunk: int = 512,
    logit_softcap: float | None = None,
) -> jnp.ndarray:
    """Cross-entropy without materializing [B, L, V] logits: scan over
    sequence chunks, rematerializing logits in the backward pass."""
    b, l, d = x.shape
    v = emb_table.shape[0]
    chunk = min(chunk, l)
    n_chunks = math.ceil(l / chunk)
    pad = n_chunks * chunk - l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xs = x.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    ys = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(carry, xy):
        xc, yc = xy  # [B, chunk, d], [B, chunk]
        logits = (xc @ emb_table.T.astype(xc.dtype)).astype(jnp.float32)
        if logit_softcap:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.clip(yc, 0)[..., None], axis=-1
        )[..., 0]
        valid = yc >= 0
        nll = jnp.where(valid, logz - gold, 0.0)
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    (total, count), _ = jax.lax.scan(chunk_loss, (0.0, 0), (xs, ys))
    return total / jnp.maximum(count, 1)
