"""RWKV-6 "Finch" (attention-free, data-dependent decay) — rwkv6-3b.

Core recurrence per head (k-dim i, v-dim j):
    y_t[j] = sum_i r_t[i] * (S[i,j] + u[i] * k_t[i] * v_t[j])
    S[i,j] <- w_t[i] * S[i,j] + k_t[i] * v_t[j]
with the *data-dependent* decay  w_t = exp(-exp(w0 + tanh(x W_A) W_B))  —
the defining RWKV-6 feature (arXiv:2404.05892).  Token-shift interpolation
uses learned per-channel mixing (the paper additionally LoRAs the mixing
coefficients; simplification noted in DESIGN.md).

The time scan is chunked: an outer checkpointed scan over chunks bounds
backward-pass memory; the inner scan advances one token at a time.
"""
from __future__ import annotations

import math
import os
from functools import partial

# tokens processed per scan step (perf knob; see _wkv_scan)
_WKV_UNROLL = int(os.environ.get("REPRO_WKV_UNROLL", "8"))

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

from .layers import (
    _dense_init,
    apply_norm,
    chunked_cross_entropy,
    embed,
    init_embedding,
    init_linear,
    init_norm,
    layer_norm,
)

_LORA = 64


def init_block(key, cfg: ArchConfig) -> dict:
    d, h, hd, f = cfg.d_model, cfg.n_heads, cfg.hd, cfg.d_ff
    ks = jax.random.split(key, 12)
    tm = {
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_v": jnp.full((d,), 0.5, jnp.float32),
        "mu_g": jnp.full((d,), 0.5, jnp.float32),
        "mu_w": jnp.full((d,), 0.5, jnp.float32),
        "w0": jnp.full((d,), -2.0, jnp.float32),
        "wA": _dense_init(ks[0], (d, _LORA)),
        "wB": _dense_init(ks[1], (_LORA, d), scale=0.01),
        "Wr": _dense_init(ks[2], (d, d)),
        "Wk": _dense_init(ks[3], (d, d)),
        "Wv": _dense_init(ks[4], (d, d)),
        "Wg": _dense_init(ks[5], (d, d)),
        "Wo": _dense_init(ks[6], (d, d)),
        "u": jnp.zeros((h, hd), jnp.float32),
        "ln_x": {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)},
    }
    cm = {
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "Wk": _dense_init(ks[7], (d, f)),
        "Wv": _dense_init(ks[8], (f, d)),
        "Wr": _dense_init(ks[9], (d, d)),
    }
    return {
        "ln1": init_norm("layer", d),
        "tm": tm,
        "ln2": init_norm("layer", d),
        "cm": cm,
    }


def init_params(cfg: ArchConfig, key) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 3)
    blocks = [init_block(keys[i], cfg) for i in range(cfg.n_layers)]
    return {
        "embed": init_embedding(keys[-1], cfg.vocab, cfg.d_model),
        "ln0": init_norm("layer", cfg.d_model),
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "final_norm": init_norm("layer", cfg.d_model),
        "lm_head": init_linear(keys[-2], cfg.d_model, cfg.vocab),
    }


def _shift(x: jnp.ndarray, prev: jnp.ndarray | None = None) -> jnp.ndarray:
    """Previous-token version of x; ``prev`` is the carried last token."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _wkv_scan(
    r, k, v, w, u, s0, chunk: int, unroll: int = 8
):  # all [B, L, H, hd] except u [H, hd]; s0 [B, H, hd, hd] f32
    """Chunked + token-blocked wkv recurrence.

    ``unroll`` tokens are processed per scan step (§Perf iteration: the
    [B,H,hd,hd] state round-trips HBM once per *block* instead of once per
    token — an 8x cut of the dominant memory-roofline term); ``chunk``
    bounds backward-pass memory via an outer checkpointed scan."""
    b, l, h, hd = r.shape
    chunk = min(chunk, l)
    unroll = max(1, min(unroll, chunk))
    if chunk % unroll:
        unroll = 1
    n_chunks = math.ceil(l / chunk)
    pad = n_chunks * chunk - l
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, w = z(r), z(k), z(v), jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)

    def to_chunks(a):  # [B, L, H, hd] -> [n, chunk/u, u, B, H, hd]
        x = a.reshape(b, n_chunks, chunk // unroll, unroll, h, hd)
        return x.transpose(1, 2, 3, 0, 4, 5)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))

    @jax.checkpoint
    def outer(s, xs):
        rx, kx, vx, wx = xs

        def inner(s, step):
            rt, kt, vt, wt = step  # [u, B, H, hd]
            ys = []
            for t in range(unroll):  # state stays on-chip across the block
                kv = kt[t][..., :, None] * vt[t][..., None, :]
                ys.append(
                    jnp.einsum("bhi,bhij->bhj", rt[t], s + u[None, :, :, None] * kv)
                )
                s = wt[t][..., :, None] * s + kv
            return s, jnp.stack(ys)

        s, ys = jax.lax.scan(inner, s, (rx, kx, vx, wx))
        return s, ys

    s, ys = jax.lax.scan(outer, s0, (rc, kc, vc, wc))
    # ys: [n, chunk/u, u, B, H, hd] -> [B, L, H, hd]
    ys = ys.reshape(n_chunks * chunk, b, h, hd).transpose(1, 0, 2, 3)
    return ys[:, :l], s


def time_mix(tm: dict, x: jnp.ndarray, cfg: ArchConfig, s0=None, x_prev=None, chunk: int = 64):
    b, l, d = x.shape
    h, hd = cfg.n_heads, cfg.hd
    xs = _shift(x, x_prev)

    def lerp(mu):
        return x + (xs - x) * mu.astype(x.dtype)

    r = (lerp(tm["mu_r"]) @ tm["Wr"].astype(x.dtype)).reshape(b, l, h, hd)
    k = (lerp(tm["mu_k"]) @ tm["Wk"].astype(x.dtype)).reshape(b, l, h, hd)
    v = (lerp(tm["mu_v"]) @ tm["Wv"].astype(x.dtype)).reshape(b, l, h, hd)
    g = jax.nn.silu(lerp(tm["mu_g"]) @ tm["Wg"].astype(x.dtype))
    lw = lerp(tm["mu_w"]).astype(jnp.float32)
    w = jnp.exp(
        -jnp.exp(
            tm["w0"] + jnp.tanh(lw @ tm["wA"]) @ tm["wB"]
        )
    ).reshape(b, l, h, hd)

    if s0 is None:
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    # r/k/v scan-IO dtype is a perf knob (halves the AD-saved residual
    # traffic); the state and decay stay f32 for numerical fidelity.
    io = jnp.bfloat16 if os.environ.get("REPRO_WKV_IO_DTYPE") == "bf16" else jnp.float32
    y, s = _wkv_scan(
        r.astype(io), k.astype(io), v.astype(io),
        w, tm["u"], s0, chunk, unroll=_WKV_UNROLL,
    )
    # per-head group norm: normalize within each head, scale per channel
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = ((yf - mu) ** 2).mean(-1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + 1e-5)
    y = (
        yn.reshape(b, l, d) * tm["ln_x"]["scale"] + tm["ln_x"]["bias"]
    ).astype(x.dtype)
    out = (y.astype(x.dtype) * g) @ tm["Wo"].astype(x.dtype)
    return out, s, x[:, -1]


def channel_mix(cm: dict, x: jnp.ndarray, x_prev=None):
    xs = _shift(x, x_prev)

    def lerp(mu):
        return x + (xs - x) * mu.astype(x.dtype)

    k = jnp.square(jax.nn.relu(lerp(cm["mu_k"]) @ cm["Wk"].astype(x.dtype)))
    v = k @ cm["Wv"].astype(x.dtype)
    r = jax.nn.sigmoid(lerp(cm["mu_r"]) @ cm["Wr"].astype(x.dtype))
    return r * v, x[:, -1]


def _block_apply(cfg, chunk, blk, x):
    from .layers import constrain_activations

    x = constrain_activations(x)
    h = apply_norm("layer", blk["ln1"], x)
    y, _, _ = time_mix(blk["tm"], h, cfg, chunk=chunk)
    x = x + y
    h = apply_norm("layer", blk["ln2"], x)
    y, _ = channel_mix(blk["cm"], h)
    return x + y


def forward_hidden(
    cfg: ArchConfig,
    params: dict,
    tokens: jnp.ndarray,
    prefix_embeds=None,
    dtype=jnp.bfloat16,
    remat: bool = True,
    chunk: int = 64,
) -> jnp.ndarray:
    x = embed(params["embed"], tokens, dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(dtype), x], axis=1)
    x = apply_norm("layer", params["ln0"], x)
    body = partial(_block_apply, cfg, chunk)
    if remat:
        body = jax.checkpoint(body)

    def step(x, blk):
        return body(blk, x), None

    x, _ = jax.lax.scan(step, x, params["blocks"])
    return apply_norm("layer", params["final_norm"], x)


def loss_fn(cfg, params, batch, dtype=jnp.bfloat16, remat=True, loss_chunk=512):
    tokens = batch["tokens"]
    h = forward_hidden(cfg, params, tokens, dtype=dtype, remat=remat)
    table = params["lm_head"]["w"].T
    return chunked_cross_entropy(h[:, :-1, :], table, tokens[:, 1:], chunk=loss_chunk)


# ------------------------------------------------------------------ serving
def init_state(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    """Recurrent 'cache': O(1) in context length (the long_500k story)."""
    l, b, h, hd, d = cfg.n_layers, batch, cfg.n_heads, cfg.hd, cfg.d_model
    return {
        "wkv": jnp.zeros((l, b, h, hd, hd), jnp.float32),
        "x_tm": jnp.zeros((l, b, d), dtype),
        "x_cm": jnp.zeros((l, b, d), dtype),
    }


def decode_step(cfg, params, state, tokens, pos=None, dtype=jnp.bfloat16):
    """One token step; pos is unused (state is position-free)."""
    x = embed(params["embed"], tokens, dtype)  # [B, 1, d]
    x = apply_norm("layer", params["ln0"], x)

    def step(x, scanned):
        blk, s_wkv, x_tm, x_cm = scanned
        h = apply_norm("layer", blk["ln1"], x)
        y, s_wkv, last_tm = time_mix(blk["tm"], h, cfg, s0=s_wkv, x_prev=x_tm, chunk=1)
        x = x + y
        h = apply_norm("layer", blk["ln2"], x)
        y, last_cm = channel_mix(blk["cm"], h, x_prev=x_cm)
        x = x + y
        return x, (s_wkv, last_tm, last_cm)

    x, (wkv, x_tm, x_cm) = jax.lax.scan(
        step, x, (params["blocks"], state["wkv"], state["x_tm"], state["x_cm"])
    )
    x = apply_norm("layer", params["final_norm"], x)
    logits = (x[:, -1, :] @ params["lm_head"]["w"].astype(x.dtype)).astype(jnp.float32)
    return logits, {"wkv": wkv, "x_tm": x_tm, "x_cm": x_cm}
