"""Mamba2 (SSD) blocks + the Zamba2 hybrid (zamba2-2.7b).

Mamba2 recurrence per head (state dim s, head dim p):
    S_t = exp(dt_t * A_h) * S_{t-1} + (dt_t * x_t) ⊗ B_t
    y_t = S_t @ C_t + D_h * x_t
with scalar A per head, shared B/C across heads (ngroups=1), a short causal
depthwise conv on the SSM input, and gated-RMSNorm output (arXiv:2405.21060).

Zamba2 (arXiv:2411.15242): a stack of Mamba2 blocks with one *shared*
transformer block (attention + MLP, same parameters each time) applied every
``hybrid_period`` layers.  (The paper adds per-invocation LoRA deltas on the
shared block; omitted — noted in DESIGN.md.)
"""
from __future__ import annotations

import math
import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

from .layers import (
    _dense_init,
    apply_norm,
    attention,
    attention_decode,
    chunked_cross_entropy,
    embed,
    init_attention,
    init_embedding,
    init_mlp,
    init_norm,
    mlp,
    rms_norm,
)
from .transformer import attn_config, logits_table

_CONV_K = 4


def init_mamba_block(key, cfg: ArchConfig) -> dict:
    d, di, st, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 3)
    return {
        "ln": init_norm(cfg.norm, d),
        "in_proj": _dense_init(ks[0], (d, 2 * di + 2 * st + h)),
        "conv_w": _dense_init(ks[1], (_CONV_K, di), scale=0.5),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -1.0, jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[2], (di, d)),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, state=None):
    """Depthwise causal conv over time. x [B, L, di]; w [K, di].
    ``state`` carries the last K-1 inputs for decode. Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)
    y = sum(
        xx[:, i : i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(k)
    ) + b.astype(x.dtype)
    return y, xx[:, -(k - 1) :, :]


_SSD_UNROLL = int(os.environ.get("REPRO_SSD_UNROLL", "8"))


def _ssd_scan(xh, dt, decay, B, C, s0, chunk: int, unroll: int | None = None):
    """xh [B,L,H,p]; dt/decay [B,L,H]; B/C [B,L,s]; s0 [B,H,p,s] f32.

    ``unroll`` tokens per scan step keep the [B,H,p,s] state on-chip across
    a token block (§Perf: cuts the state's HBM round-trips by the block
    size — the dominant memory-roofline term of the naive scan)."""
    b, l, h, p = xh.shape
    s_dim = B.shape[-1]
    chunk = min(chunk, l)
    unroll = _SSD_UNROLL if unroll is None else unroll
    unroll = max(1, min(unroll, chunk))
    if chunk % unroll:
        unroll = 1
    n_chunks = math.ceil(l / chunk)
    pad = n_chunks * chunk - l
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    def tc(a, trail):  # [B, L, ...] -> [n, chunk/u, u, B, ...]
        x = a.reshape((b, n_chunks, chunk // unroll, unroll) + trail)
        return jnp.moveaxis(x, 0, 3)

    xc = tc(xh, (h, p))
    dc = tc(dt, (h,))
    gc = tc(decay, (h,))
    bc = tc(B, (s_dim,))
    cc = tc(C, (s_dim,))

    @jax.checkpoint
    def outer(s, xs):
        xck, dck, gck, bck, cck = xs

        def inner(s, step):
            xt, dtt, gt, bt, ct = step  # [u,B,H,p] [u,B,H] [u,B,H] [u,B,s] [u,B,s]
            ys = []
            for t in range(unroll):
                dx = (dtt[t][..., None] * xt[t]).astype(jnp.float32)
                s = gt[t][..., None, None].astype(jnp.float32) * s + dx[
                    ..., None
                ] * bt[t][:, None, None, :].astype(jnp.float32)
                ys.append(jnp.einsum("bhps,bs->bhp", s, ct[t].astype(jnp.float32)))
            return s, jnp.stack(ys)

        return jax.lax.scan(inner, s, (xck, dck, gck, bck, cck))

    s, ys = jax.lax.scan(outer, s0, (xc, dc, gc, bc, cc))
    ys = ys.reshape(n_chunks * chunk, b, h, p).transpose(1, 0, 2, 3)
    return ys[:, :l], s


def mamba_mix(p: dict, x: jnp.ndarray, cfg: ArchConfig, ssm_state=None, conv_state=None, chunk: int = 64):
    b, l, d = x.shape
    di, st, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hd = di // h
    proj = x @ p["in_proj"].astype(x.dtype)
    z, xin, bmat, cmat, dtr = jnp.split(proj, [di, 2 * di, 2 * di + st, 2 * di + 2 * st], axis=-1)
    xin, conv_state = _causal_conv(xin, p["conv_w"], p["conv_b"], conv_state)
    xin = jax.nn.silu(xin)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])  # [B,L,H]
    decay = jnp.exp(dt * -jnp.exp(p["A_log"]))
    xh = xin.reshape(b, l, h, hd)
    if ssm_state is None:
        ssm_state = jnp.zeros((b, h, hd, st), jnp.float32)
    y, s = _ssd_scan(
        xh.astype(jnp.float32), dt, decay,
        bmat.astype(jnp.float32), cmat.astype(jnp.float32), ssm_state, chunk,
    )
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, l, di).astype(x.dtype)
    y = rms_norm(None, y * jax.nn.silu(z)) * p["norm_scale"].astype(x.dtype)
    return y @ p["out_proj"].astype(x.dtype), s, conv_state


def init_shared_block(key, cfg: ArchConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_norm(cfg.norm, cfg.d_model),
        "attn": init_attention(k1, attn_config(cfg)),
        "ln2": init_norm(cfg.norm, cfg.d_model),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, gated=False),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 4)
    blocks = [init_mamba_block(keys[i], cfg) for i in range(cfg.n_layers)]
    params = {
        "embed": init_embedding(keys[-1], cfg.vocab, cfg.d_model),
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "final_norm": init_norm(cfg.norm, cfg.d_model),
    }
    if cfg.hybrid_period:
        params["shared_attn"] = init_shared_block(keys[-2], cfg)
    if not cfg.tie_embeddings:
        from .layers import init_linear

        params["lm_head"] = init_linear(keys[-3], cfg.d_model, cfg.vocab)
    return params


def _groups(cfg: ArchConfig) -> tuple[int, int]:
    period = cfg.hybrid_period or cfg.n_layers
    assert cfg.n_layers % period == 0, "n_layers must divide hybrid_period"
    return cfg.n_layers // period, period


def _shared_apply(cfg, shared, x):
    h = apply_norm(cfg.norm, shared["ln1"], x)
    x = x + attention(shared["attn"], attn_config(cfg), h)
    h = apply_norm(cfg.norm, shared["ln2"], x)
    return x + mlp(shared["mlp"], h, cfg.act)


def forward_hidden(
    cfg: ArchConfig,
    params: dict,
    tokens: jnp.ndarray,
    prefix_embeds=None,
    dtype=jnp.bfloat16,
    remat: bool = True,
    chunk: int = 64,
) -> jnp.ndarray:
    x = embed(params["embed"], tokens, dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(dtype), x], axis=1)
    n_groups, period = _groups(cfg)
    stacked = params["blocks"]
    grouped = jax.tree.map(
        lambda a: a.reshape((n_groups, period) + a.shape[1:]), stacked
    )

    from .layers import constrain_activations

    def mamba_body(blk, x):
        x = constrain_activations(x)
        h = apply_norm(cfg.norm, blk["ln"], x)
        y, _, _ = mamba_mix(blk, h, cfg, chunk=chunk)
        return x + y

    body = jax.checkpoint(mamba_body) if remat else mamba_body

    for g in range(n_groups):
        grp = jax.tree.map(lambda a: a[g], grouped)

        def step(x, blk):
            return body(blk, x), None

        x, _ = jax.lax.scan(step, x, grp)
        if cfg.hybrid_period:
            shared_body = (
                jax.checkpoint(partial(_shared_apply, cfg)) if remat else partial(_shared_apply, cfg)
            )
            x = shared_body(params["shared_attn"], x)
    return apply_norm(cfg.norm, params["final_norm"], x)


def loss_fn(cfg, params, batch, dtype=jnp.bfloat16, remat=True, loss_chunk=512):
    tokens = batch["tokens"]
    h = forward_hidden(cfg, params, tokens, dtype=dtype, remat=remat)
    return chunked_cross_entropy(
        h[:, :-1, :], logits_table(cfg, params), tokens[:, 1:], chunk=loss_chunk
    )


# ------------------------------------------------------------------ serving
def init_state(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> dict:
    l, b = cfg.n_layers, batch
    h, hd, st, di = cfg.ssm_heads, cfg.d_inner // cfg.ssm_heads, cfg.ssm_state, cfg.d_inner
    n_groups, _ = _groups(cfg)
    state = {
        "ssm": jnp.zeros((l, b, h, hd, st), jnp.float32),
        "conv": jnp.zeros((l, b, _CONV_K - 1, di), dtype),
    }
    if cfg.hybrid_period:
        state["k"] = jnp.zeros((n_groups, b, cfg.n_kv, max_seq, cfg.hd), dtype)
        state["v"] = jnp.zeros((n_groups, b, cfg.n_kv, max_seq, cfg.hd), dtype)
    return state


def decode_step(cfg, params, state, tokens, pos, dtype=jnp.bfloat16):
    x = embed(params["embed"], tokens, dtype)
    n_groups, period = _groups(cfg)
    ssm_new, conv_new, k_new, v_new = [], [], [], []
    acfg = attn_config(cfg)
    for g in range(n_groups):
        for i in range(period):
            li = g * period + i
            blk = jax.tree.map(lambda a: a[li], params["blocks"])
            h = apply_norm(cfg.norm, blk["ln"], x)
            y, s, cs = mamba_mix(
                blk, h, cfg, ssm_state=state["ssm"][li], conv_state=state["conv"][li], chunk=1
            )
            x = x + y
            ssm_new.append(s)
            conv_new.append(cs)
        if cfg.hybrid_period:
            shared = params["shared_attn"]
            h = apply_norm(cfg.norm, shared["ln1"], x)
            y, kc, vc = attention_decode(
                shared["attn"], acfg, h, state["k"][g], state["v"][g], pos
            )
            x = x + y
            h = apply_norm(cfg.norm, shared["ln2"], x)
            x = x + mlp(shared["mlp"], h, cfg.act)
            k_new.append(kc)
            v_new.append(vc)
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = (x[:, -1, :] @ logits_table(cfg, params).T.astype(x.dtype)).astype(jnp.float32)
    new_state = {"ssm": jnp.stack(ssm_new), "conv": jnp.stack(conv_new)}
    if cfg.hybrid_period:
        new_state["k"] = jnp.stack(k_new)
        new_state["v"] = jnp.stack(v_new)
    return logits, new_state
