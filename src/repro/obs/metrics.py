"""Process-local metrics registry: counters, gauges, histograms
(DESIGN.md §10).

The streaming stack already *computes* its telemetry — shed/deferred
rows, breaker transitions, fair-share trims, replan triggers, checkpoint
sizes — but each number lives on whichever object produced it.  The
registry gives them one label-aware home with two export surfaces:

  * ``snapshot()`` — a plain nested dict with deterministically sorted
    keys.  Counters and gauges driven by seeded streams are bit-stable
    run over run (the determinism contract ``pytest -m obs`` asserts);
    wall-time lives ONLY in histograms, whose bucket *counts* are stable
    but whose ``sum`` is not — consumers that diff snapshots compare
    ``counters``/``gauges``.
  * ``to_prometheus()`` — the Prometheus text exposition format, so a
    scrape endpoint is one ``web.Response(registry.to_prometheus())``
    away.

Labels are plain kwargs (``registry.counter("stream_shed_rows_total",
tenant="q1", rel="R")``); the instrument key is ``(name, sorted label
items)``, so the same call site with a different tenant label yields an
isolated instrument — the per-tenant isolation the tenancy tests assert.
A disabled registry (``MetricsRegistry(enabled=False)``) hands every
caller shared null instruments whose ``inc``/``set``/``observe`` are
no-ops, keeping the wired-but-off cost to a dict miss per lookup.

Instruments lock on mutation: ``mapreduce.straggler`` observes attempt
latencies from its worker pool, so histograms must tolerate threads.
"""
from __future__ import annotations

import threading

# default latency buckets (seconds): 100µs .. ~100s, exponential
DEFAULT_BUCKETS = tuple(1e-4 * (4.0**i) for i in range(11))

_LabelKey = tuple[tuple[str, str], ...]


def _labelkey(labels: dict) -> _LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(key: _LabelKey, extra: tuple[tuple[str, str], ...] = ()) -> str:
    items = key + extra
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in items) + "}"


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("value", "_lock")

    def __init__(self):
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up (inc by {amount})")
        with self._lock:
            self.value += amount


class Gauge:
    """A value that can go anywhere."""

    __slots__ = ("value", "_lock")

    def __init__(self):
        self.value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount


class Histogram:
    """Fixed-bucket histogram (cumulative on export, Prometheus-style)."""

    __slots__ = ("buckets", "counts", "sum", "count", "_lock")

    def __init__(self, buckets: tuple[float, ...] = DEFAULT_BUCKETS):
        if list(buckets) != sorted(buckets) or not buckets:
            raise ValueError("histogram buckets must be sorted and non-empty")
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)  # last = +Inf
        self.sum = 0.0
        self.count = 0
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        v = float(value)
        with self._lock:
            for i, b in enumerate(self.buckets):
                if v <= b:
                    self.counts[i] += 1
                    break
            else:
                self.counts[-1] += 1
            self.sum += v
            self.count += 1

    def cumulative(self) -> list[int]:
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out


class _NullInstrument:
    """Shared no-op stand-in handed out by a disabled registry."""

    __slots__ = ()
    value = 0.0
    sum = 0.0
    count = 0

    def inc(self, amount: float = 1.0) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass


NULL_INSTRUMENT = _NullInstrument()


class MetricsRegistry:
    """Label-aware get-or-create registry with dict + Prometheus export."""

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._counters: dict[tuple[str, _LabelKey], Counter] = {}
        self._gauges: dict[tuple[str, _LabelKey], Gauge] = {}
        self._histograms: dict[tuple[str, _LabelKey], Histogram] = {}
        self._lock = threading.Lock()

    # ---- get-or-create -----------------------------------------------------
    def counter(self, name: str, **labels) -> Counter | _NullInstrument:
        if not self.enabled:
            return NULL_INSTRUMENT
        key = (name, _labelkey(labels))
        inst = self._counters.get(key)
        if inst is None:
            with self._lock:
                inst = self._counters.setdefault(key, Counter())
        return inst

    def gauge(self, name: str, **labels) -> Gauge | _NullInstrument:
        if not self.enabled:
            return NULL_INSTRUMENT
        key = (name, _labelkey(labels))
        inst = self._gauges.get(key)
        if inst is None:
            with self._lock:
                inst = self._gauges.setdefault(key, Gauge())
        return inst

    def histogram(
        self,
        name: str,
        buckets: tuple[float, ...] = DEFAULT_BUCKETS,
        **labels,
    ) -> Histogram | _NullInstrument:
        if not self.enabled:
            return NULL_INSTRUMENT
        key = (name, _labelkey(labels))
        inst = self._histograms.get(key)
        if inst is None:
            with self._lock:
                inst = self._histograms.setdefault(key, Histogram(buckets))
        return inst

    # ---- export ------------------------------------------------------------
    def snapshot(self) -> dict:
        """Deterministic nested dict: series keyed ``name{labels}``.
        Counters/gauges are reproducible under seeded streams; histogram
        ``sum`` carries wall time and is excluded from determinism
        contracts (compare ``counters``/``gauges``)."""
        counters = {
            name + _fmt_labels(lk): c.value
            for (name, lk), c in sorted(self._counters.items())
        }
        gauges = {
            name + _fmt_labels(lk): g.value
            for (name, lk), g in sorted(self._gauges.items())
        }
        histograms = {
            name + _fmt_labels(lk): {
                "count": h.count,
                "sum": h.sum,
                "buckets": {
                    ("+Inf" if i == len(h.buckets) else repr(h.buckets[i])): c
                    for i, c in enumerate(h.cumulative())
                },
            }
            for (name, lk), h in sorted(self._histograms.items())
        }
        return {
            "counters": counters,
            "gauges": gauges,
            "histograms": histograms,
        }

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (one TYPE line per family)."""
        lines: list[str] = []
        seen_type: set[str] = set()

        def type_line(name: str, kind: str) -> None:
            if name not in seen_type:
                seen_type.add(name)
                lines.append(f"# TYPE {name} {kind}")

        for (name, lk), c in sorted(self._counters.items()):
            type_line(name, "counter")
            lines.append(f"{name}{_fmt_labels(lk)} {_fmt_value(c.value)}")
        for (name, lk), g in sorted(self._gauges.items()):
            type_line(name, "gauge")
            lines.append(f"{name}{_fmt_labels(lk)} {_fmt_value(g.value)}")
        for (name, lk), h in sorted(self._histograms.items()):
            type_line(name, "histogram")
            cum = h.cumulative()
            for i, b in enumerate(h.buckets):
                le = _fmt_labels(lk, (("le", repr(b)),))
                lines.append(f"{name}_bucket{le} {cum[i]}")
            inf = _fmt_labels(lk, (("le", "+Inf"),))
            lines.append(f"{name}_bucket{inf} {cum[-1]}")
            lines.append(f"{name}_sum{_fmt_labels(lk)} {_fmt_value(h.sum)}")
            lines.append(f"{name}_count{_fmt_labels(lk)} {h.count}")
        return "\n".join(lines) + ("\n" if lines else "")


def _fmt_value(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(v)
