"""Observability for the streaming SharesSkew stack (DESIGN.md §10).

Three parts, one facade:

  * :mod:`repro.obs.trace` — nested-span tracer, Chrome/Perfetto export,
    free when disabled;
  * :mod:`repro.obs.metrics` — label-aware counter/gauge/histogram
    registry with dict snapshot + Prometheus text dump;
  * :mod:`repro.obs.skewscope` — exact per-reducer load telemetry (the
    paper's cost objective), imbalance factor, HH hit rate, CMS error.

:class:`Observability` bundles one tracer + one registry + (optionally)
one SkewScope per engine, and injects a ``tenant`` label into every
metric a tenant engine records, so N engines sharing one registry stay
isolated series-wise.  Engines accept the facade as a constructor
argument; :data:`NULL_OBS` (everything disabled) is the default, so
unwired call sites cost a predicate check and nothing else.

:class:`ObsPolicy` is the *user-facing* switch carried on
``StreamConfig``/``TenancyPolicy`` — plain frozen-dataclass bools that
checkpoint round-trip like every other config knob; the engine
constructs the matching facade from it at ``__init__``/``restore``.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.obs.metrics import (  # noqa: F401  (re-exports)
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NULL_INSTRUMENT,
)
from repro.obs.skewscope import (  # noqa: F401
    SkewScope,
    SkewSnapshot,
    cms_window_error,
    hh_hit_counts,
)
from repro.obs.trace import NULL_SPAN, Tracer  # noqa: F401


@dataclasses.dataclass(frozen=True)
class ObsPolicy:
    """What to observe.  Everything defaults off — the zero-cost path."""

    trace: bool = False  # nested spans + Chrome/Perfetto export
    metrics: bool = False  # counters/gauges/histograms registry
    skewscope: bool = False  # exact per-reducer load accounting

    @property
    def any(self) -> bool:
        return self.trace or self.metrics or self.skewscope


class Observability:
    """One engine's bundle of tracer + registry + skewscope.

    ``tenant`` (when non-empty) is injected as a label into every
    counter/gauge/histogram lookup, which is the whole per-tenant
    isolation mechanism: same registry, disjoint series.
    """

    def __init__(
        self,
        policy: ObsPolicy = ObsPolicy(),
        tracer: Tracer | None = None,
        metrics: MetricsRegistry | None = None,
        tenant: str = "",
        arities: Mapping[str, int] | None = None,
    ):
        self.policy = policy
        self.tracer = tracer if tracer is not None else Tracer(enabled=policy.trace)
        self.metrics = (
            metrics if metrics is not None else MetricsRegistry(enabled=policy.metrics)
        )
        self.tenant = str(tenant)
        self.skew: SkewScope | None = (
            SkewScope(arities) if policy.skewscope and arities is not None else None
        )

    def for_tenant(
        self, tenant: str, arities: Mapping[str, int] | None = None
    ) -> "Observability":
        """A tenant-scoped view: SHARED tracer + registry, own label
        (and own SkewScope — reducer id spaces differ per query)."""
        return Observability(
            policy=self.policy,
            tracer=self.tracer,
            metrics=self.metrics,
            tenant=tenant,
            arities=arities,
        )

    # ---- label-injecting metric helpers ------------------------------------
    def _labels(self, labels: dict) -> dict:
        if self.tenant:
            labels.setdefault("tenant", self.tenant)
        return labels

    def counter(self, name: str, **labels):
        return self.metrics.counter(name, **self._labels(labels))

    def gauge(self, name: str, **labels):
        return self.metrics.gauge(name, **self._labels(labels))

    def histogram(self, name: str, buckets=DEFAULT_BUCKETS, **labels):
        return self.metrics.histogram(name, buckets=buckets, **self._labels(labels))

    # ---- tracing passthrough (so call sites hold one object) ---------------
    def span(self, name: str, cat: str = "stream", args: dict | None = None):
        return self.tracer.span(name, cat, args)

    def instant(self, name: str, cat: str = "stream", args: dict | None = None):
        return self.tracer.instant(name, cat, args)


#: The default wired into engines: everything off, every hook free.
NULL_OBS = Observability()

__all__ = [
    "ObsPolicy",
    "Observability",
    "NULL_OBS",
    "Tracer",
    "NULL_SPAN",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "NULL_INSTRUMENT",
    "DEFAULT_BUCKETS",
    "SkewScope",
    "SkewSnapshot",
    "hh_hit_counts",
    "cms_window_error",
]
