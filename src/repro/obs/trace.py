"""Low-overhead nested-span tracing for the streaming engine (DESIGN.md §10).

One batch through ``StreamingJoinEngine.ingest`` is a tree of phases —
ingest → sketch update → route → delta join → retention/expiry → replan
(solve / migrate / first-kernel compile) → recovery (detect / replay /
repair / verify) — and the only way to see where a batch's time goes is
to clock those phases *as a tree*, not as a flat list of
``perf_counter`` deltas.  ``Tracer`` is that clock:

  * spans are context managers over ``time.perf_counter_ns`` (injectable
    for tests), nested by a plain stack, each stamped with the current
    *batch index* plus a per-batch sequence number — the batch-clocked
    span id, so two runs over the same seeded stream produce the same id
    sequence;
  * ``to_chrome()`` exports the Chrome/Perfetto trace-event JSON format
    (``ph: "X"`` complete events in microseconds), so
    ``tracer.dump("out.json")`` loads directly in ``chrome://tracing`` /
    https://ui.perfetto.dev and renders the nesting by time containment;
  * *disabled is free*: a disabled tracer's ``span()`` returns one
    module-level singleton — no span object, no args dict, no clock
    read, no per-call allocation — so leaving trace hooks in the fused
    hot path costs a predicate check per call and nothing else.  Callers
    that want to attach argument dicts guard their construction with
    ``tracer.enabled`` (the ``args=None`` default keeps the common call
    allocation-free).

The tracer is deliberately single-threaded (the engine's batch loop);
thread-fanout code (``mapreduce.straggler``) records per-attempt
latencies into ``obs.metrics`` histograms instead, which lock.
"""
from __future__ import annotations

import json
import time
from typing import Callable


class _NullSpan:
    """The disabled-tracer span: one shared instance, no state, no cost."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """One open span; closing it appends a finished event to the tracer."""

    __slots__ = ("_tracer", "name", "cat", "args", "span_id", "_start_ns")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.span_id = ""
        self._start_ns = 0

    def __enter__(self) -> "_Span":
        t = self._tracer
        t._seq += 1
        self.span_id = f"{t._batch}.{t._seq}"
        t._stack.append(self)
        self._start_ns = t._clock_ns()
        return self

    def __exit__(self, *exc) -> bool:
        t = self._tracer
        end_ns = t._clock_ns()
        if t._stack and t._stack[-1] is self:
            t._stack.pop()
        args = dict(self.args) if self.args else {}
        args["batch"] = t._batch
        args["span_id"] = self.span_id
        t.events.append(
            {
                "name": self.name,
                "cat": self.cat,
                "ph": "X",
                "ts": (self._start_ns - t._epoch_ns) / 1e3,  # µs
                "dur": (end_ns - self._start_ns) / 1e3,
                "pid": t.pid,
                "tid": t.tid,
                "args": args,
            }
        )
        return False


class Tracer:
    """Nested-span tracer with Chrome/Perfetto trace-event export.

    ``enabled=False`` (the default) makes every hook free: ``span()``
    returns ``NULL_SPAN`` and ``instant()`` returns immediately.
    """

    def __init__(
        self,
        enabled: bool = False,
        clock_ns: Callable[[], int] | None = None,
        pid: int = 0,
        tid: int = 0,
    ):
        self.enabled = bool(enabled)
        self._clock_ns = clock_ns or time.perf_counter_ns
        self.pid = int(pid)
        self.tid = int(tid)
        self._epoch_ns = self._clock_ns()
        self._batch = -1  # set_batch() before the first ingest
        self._seq = 0
        self._stack: list[_Span] = []
        self.events: list[dict] = []

    # ---- recording ---------------------------------------------------------
    def set_batch(self, batch: int) -> None:
        """Advance the batch clock: span ids restart at ``<batch>.1``."""
        if not self.enabled:
            return
        self._batch = int(batch)
        self._seq = 0

    def span(self, name: str, cat: str = "stream", args: dict | None = None):
        """Context manager clocking one phase.  ``args`` (optional dict)
        lands in the trace event; pass it pre-built and guard expensive
        construction with ``tracer.enabled``."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "stream", args: dict | None = None) -> None:
        """A zero-duration marker (``ph: "i"``) — decisions, triggers."""
        if not self.enabled:
            return
        self._seq += 1
        a = dict(args) if args else {}
        a["batch"] = self._batch
        a["span_id"] = f"{self._batch}.{self._seq}"
        self.events.append(
            {
                "name": name,
                "cat": cat,
                "ph": "i",
                "s": "t",  # thread-scoped instant
                "ts": (self._clock_ns() - self._epoch_ns) / 1e3,
                "pid": self.pid,
                "tid": self.tid,
                "args": a,
            }
        )

    @property
    def depth(self) -> int:
        """Current open-span nesting depth (0 outside any span)."""
        return len(self._stack)

    def clear(self) -> None:
        self.events = []
        self._stack = []
        self._seq = 0

    # ---- export ------------------------------------------------------------
    def to_chrome(self) -> dict:
        """The Chrome trace-event JSON object (Perfetto-loadable)."""
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def dump(self, path: str) -> str:
        """Write ``to_chrome()`` to ``path``; returns the path."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1)
        return path

    def span_names(self) -> list[str]:
        """Distinct event names in first-seen order (test/report helper)."""
        seen: dict[str, None] = {}
        for ev in self.events:
            seen.setdefault(ev["name"], None)
        return list(seen)
