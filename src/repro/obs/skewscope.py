"""Per-reducer load telemetry: the paper's own cost objective, observable
per batch (DESIGN.md §10).

SharesSkew's whole argument is about *load* — Beame–Koutris–Suciu
(arXiv:1401.1872) define it as the maximum bytes received by any one
reducer, and the skew variants of arXiv:1504.03247 are exactly the
regimes where that maximum detaches from the mean.  The engine has
always *routed* per-reducer arrivals; ``SkewScope`` makes them visible:

  * **exact per-reducer load** — tuples and bytes received per logical
    reducer for the current plan epoch, accumulated from the same
    ``_Routed.counts`` histograms the engine folds into carried state,
    so the tuple counts are bit-identical to the distributed shuffle's
    ``reducer_loads`` (asserted in ``pytest -m obs``).  Bytes are
    ``tuples x arity x 4`` per relation (int32 rows), summed;
  * **imbalance factor** — max/mean per-reducer load, the skew figure of
    merit (1.0 = perfectly balanced; the paper's q-bound argues this
    stays O(1) when heavy hitters are pinned);
  * **HH routing hit rate** — the fraction of ingested rows whose share-
    attribute value is pinned by the live plan, i.e. the share of traffic
    the skew machinery is actually absorbing;
  * **Count-Min estimate error** — the decayed CMS rate vs the *decay-
    weighted exact* counts over the retained window (the same geometric
    weights ``DecayingCountMin.rate`` applies), isolating pure sketch
    collision + window-truncation error: on a fully retained stream with
    no collisions the error is 0.

SkewScope mirrors the engine's ``_loads`` discipline: ``install(k)``
resets at every plan install (a replan changes the reducer id space) and
the migration re-route counts as arrivals, exactly like ``_loads``.  It
is process-local telemetry — not checkpointed; after a restore it
reflects the deterministic rebuild of the retained window.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

BYTES_PER_VALUE = 4  # int32 routing domain: every shipped cell is 4 bytes


@dataclasses.dataclass(frozen=True)
class SkewSnapshot:
    """One plan epoch's load picture (JSON-able)."""

    total_reducers: int
    total_tuples: int
    total_bytes: int
    max_tuples: int  # the BKS load: worst single reducer
    max_bytes: int
    mean_tuples: float
    imbalance: float  # max/mean tuples (1.0 when nothing arrived)
    hh_hit_rate: float  # pinned-HH share of ingested rows, cumulative
    cms_error: dict[str, float]  # per attr: mean relative rate error

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class SkewScope:
    """Per-reducer arrival accounting for the live plan epoch."""

    def __init__(self, arities: Mapping[str, int]):
        self.arities = {str(nm): int(a) for nm, a in arities.items()}
        self._tuples: dict[str, np.ndarray] = {}
        self._k = 0
        # cumulative HH-routing accounting (survives replans: it describes
        # the stream, not one plan's reducer id space)
        self.hh_rows = 0
        self.total_rows = 0
        self._cms_error: dict[str, float] = {}

    # ---- per-reducer loads -------------------------------------------------
    def install(self, total_reducers: int) -> None:
        """A plan (re)install: the reducer id space changed, start over —
        the mirror of the engine zeroing ``_loads``."""
        self._k = int(total_reducers)
        self._tuples = {
            nm: np.zeros(self._k, dtype=np.int64) for nm in self.arities
        }

    def record(self, rel_name: str, counts: np.ndarray) -> None:
        """Fold one routed batch's per-reducer arrival histogram for one
        relation (the ``_Routed.counts`` the engine already has)."""
        self._tuples[rel_name] += np.asarray(counts, dtype=np.int64)

    def tuples_per_reducer(self) -> np.ndarray:
        """[k] exact tuples received per logical reducer, all relations."""
        if not self._tuples:
            return np.zeros(0, dtype=np.int64)
        return np.sum(list(self._tuples.values()), axis=0, dtype=np.int64)

    def bytes_per_reducer(self) -> np.ndarray:
        """[k] exact bytes received per logical reducer (int32 rows)."""
        if not self._tuples:
            return np.zeros(0, dtype=np.int64)
        out = np.zeros(self._k, dtype=np.int64)
        for nm, t in self._tuples.items():
            out += t * (self.arities[nm] * BYTES_PER_VALUE)
        return out

    # ---- HH routing --------------------------------------------------------
    def record_hh(self, hh_rows: int, total_rows: int) -> None:
        self.hh_rows += int(hh_rows)
        self.total_rows += int(total_rows)

    @property
    def hh_hit_rate(self) -> float:
        return self.hh_rows / self.total_rows if self.total_rows else 0.0

    # ---- CMS error ---------------------------------------------------------
    def record_cms_error(self, errors: Mapping[str, float]) -> None:
        self._cms_error = {a: float(e) for a, e in errors.items()}

    # ---- snapshot ----------------------------------------------------------
    def snapshot(self) -> SkewSnapshot:
        t = self.tuples_per_reducer()
        b = self.bytes_per_reducer()
        total = int(t.sum())
        mean = total / self._k if self._k else 0.0
        mx = int(t.max()) if t.size else 0
        return SkewSnapshot(
            total_reducers=self._k,
            total_tuples=total,
            total_bytes=int(b.sum()),
            max_tuples=mx,
            max_bytes=int(b.max()) if b.size else 0,
            mean_tuples=mean,
            imbalance=(mx / mean) if mean > 0 else 1.0,
            hh_hit_rate=self.hh_hit_rate,
            cms_error=dict(sorted(self._cms_error.items())),
        )


# ---- free functions the engine feeds from its own state ---------------------
def hh_hit_counts(
    query, batch: Mapping[str, np.ndarray], hh_values: Mapping[str, Sequence[int]]
) -> tuple[int, int]:
    """(rows whose share-attribute value is pinned, total rows) for one
    admitted batch under the live plan's ``hh_values``.  A row counts as a
    hit when ANY of its pinned-attribute columns holds a pinned value —
    those rows route through a dedicated HH residual instead of the
    ordinary grid."""
    hits = total = 0
    pinned = {
        a: np.asarray(list(vals), dtype=np.int64)
        for a, vals in hh_values.items()
        if len(vals)
    }
    for rel in query.relations:
        rows = np.asarray(batch.get(rel.name, np.zeros((0, rel.arity))))
        n = rows.shape[0]
        total += n
        if n == 0:
            continue
        hit = np.zeros(n, dtype=bool)
        for a, vals in pinned.items():
            if a in rel.attrs:
                hit |= np.isin(rows[:, rel.index_of(a)], vals)
        hits += int(hit.sum())
    return hits, total


def cms_window_error(
    tracker,
    query,
    history: Mapping[str, Sequence[np.ndarray]],
    retained_ids: Sequence[int],
) -> dict[str, float]:
    """Per share-attribute mean relative error of the decayed Count-Min
    rate vs the decay-weighted EXACT counts over the retained window.

    The reference applies the same geometric weights as
    ``DecayingCountMin.rate`` — batch ``bid`` (0-based absolute index,
    ``T`` batches observed) contributes ``decay^(T-1-bid)`` times its
    exact value count, normalized by ``(1-g)/(1-g^T)`` — so on a window
    retaining the full stream the error isolates pure CMS collision
    overcount (always >= 0); an expired prefix shows up as the window-
    truncation share of the estimate.  Values audited are the tracker's
    own SpaceSaving candidates (threshold 0): exactly the set planning
    decisions are made from.
    """
    g = float(tracker.decay)
    T = int(tracker.batches)
    if T == 0:
        return {}
    norm = 1.0 / T if g >= 1.0 else (1.0 - g) / (1.0 - g**T)
    out: dict[str, float] = {}
    for attr in tracker.attrs:
        cand, _ = tracker.candidates_of(attr)
        if cand.size == 0:
            continue
        errs: list[float] = []
        for rel in query.relations_of(attr):
            col_idx = rel.index_of(attr)
            exact = np.zeros(cand.size, dtype=np.float64)
            for i, bid in enumerate(retained_ids):
                col = np.asarray(history[rel.name][i])[:, col_idx]
                if col.size == 0:
                    continue
                w = g ** (T - 1 - int(bid)) if g < 1.0 else 1.0
                vals, counts = np.unique(col, return_counts=True)
                pos = np.searchsorted(vals, cand)
                pos = np.clip(pos, 0, vals.size - 1)
                match = vals[pos] == cand
                exact += w * np.where(match, counts[pos], 0)
            exact *= norm
            est = tracker.rate_in(attr, rel.name, cand)
            denom = np.maximum(exact, 1e-12)
            errs.extend(np.abs(est - exact) / denom)
        if errs:
            out[attr] = float(np.mean(errs))
    return out
