"""Map-phase key generation (paper §5.2 Map step + recursive_keys).

The paper builds, per tuple and per compatible residual join, the set of
reducer keys: hash the attributes the tuple owns (marked ``h``), fix share-1
attributes (marked ``1``), and *replicate* over the grid dimensions of
share attributes the tuple lacks (marked ``r`` — the recursive_keys
enumeration).  Here that enumeration is vectorized: for each
(relation, residual) pair the replication pattern is static, so key
generation is a gather-free jnp computation emitting a dense
``[N, replication]`` block of global reducer ids (−1 where the tuple is not
relevant to the residual).
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro.core.planner import ResidualPlan, SharesSkewPlan
from repro.core.residual import ORDINARY
from repro.core.schema import RelationSchema

from .hashing import attr_seed, bucket_jnp


@dataclasses.dataclass(frozen=True)
class RouteSpec:
    """Static routing recipe for one (relation, residual) pair.

    Global reducer id = offset + sum_i coord_i * stride_i over grid attrs.
    ``hashed``: (col_in_relation, seed, dim, stride) for attrs the tuple owns.
    ``replicated``: (dim, stride) for grid attrs the tuple lacks; the tuple is
    sent to every coordinate — the paper's ``r`` mark.
    ``pins``: (col, value) equality constraints (this residual's HHs).
    ``ordinary_excludes``: (col, values[]) — attrs of ordinary type exclude
    the attribute's HH values.
    """

    rel_name: str
    residual_index: int
    offset: int
    hashed: tuple[tuple[int, int, int, int], ...]
    replicated: tuple[tuple[int, int], ...]
    pins: tuple[tuple[int, int], ...]
    ordinary_excludes: tuple[tuple[int, tuple[int, ...]], ...]

    @property
    def replication(self) -> int:
        return math.prod(d for d, _ in self.replicated) if self.replicated else 1

    # ---- vectorized recursive_keys -----------------------------------------
    def replica_offsets(self) -> np.ndarray:
        """Flat id offsets of the replicated coordinates ([replication])."""
        if not self.replicated:
            return np.zeros(1, dtype=np.int32)
        grids = np.meshgrid(
            *[np.arange(d, dtype=np.int32) for d, _ in self.replicated],
            indexing="ij",
        )
        flat = sum(
            g.reshape(-1) * np.int32(stride)
            for g, (_, stride) in zip(grids, self.replicated)
        )
        return flat.astype(np.int32)

    def destinations(self, rows: jnp.ndarray) -> jnp.ndarray:
        """[N, replication] global reducer ids; −1 where not relevant."""
        n = rows.shape[0]
        base = jnp.zeros(n, dtype=jnp.int32) + jnp.int32(self.offset)
        for col, seed, dim, stride in self.hashed:
            base = base + bucket_jnp(rows[:, col], seed, dim) * jnp.int32(stride)
        mask = jnp.ones(n, dtype=bool)
        for col, value in self.pins:
            mask &= rows[:, col] == value
        for col, values in self.ordinary_excludes:
            v = rows[:, col]
            bad = jnp.zeros(n, dtype=bool)
            for hv in values:
                bad |= v == hv
            mask &= ~bad
        rep = jnp.asarray(self.replica_offsets())  # [R]
        dest = base[:, None] + rep[None, :]
        return jnp.where(mask[:, None], dest, jnp.int32(-1))


def build_route_specs(
    plan: SharesSkewPlan, rel: RelationSchema
) -> tuple[RouteSpec, ...]:
    """All routing recipes for one relation across the plan's residuals."""
    specs = []
    for ridx, res in enumerate(plan.residuals):
        specs.append(_route_for(plan, ridx, res, rel))
    return tuple(specs)


def _route_for(
    plan: SharesSkewPlan, ridx: int, res: ResidualPlan, rel: RelationSchema
) -> RouteSpec:
    dims = dict(zip(res.grid_attrs, res.grid_dims))
    # strides: row-major over grid_attrs order
    strides: dict[str, int] = {}
    acc = 1
    for a in reversed(res.grid_attrs):
        strides[a] = acc
        acc *= dims[a]
    hashed = []
    replicated = []
    for a in res.grid_attrs:
        if a in rel.attrs:
            hashed.append((rel.index_of(a), attr_seed(ridx, a), dims[a], strides[a]))
        else:
            replicated.append((dims[a], strides[a]))
    pins = []
    excludes = []
    combo = res.combo.as_dict()
    for a, v in combo.items():
        if a not in rel.attrs:
            continue
        col = rel.index_of(a)
        if v is ORDINARY:
            hh = plan.hh_values.get(a)
            if hh is not None and len(hh):
                excludes.append((col, tuple(int(x) for x in np.asarray(hh))))
        else:
            pins.append((col, int(v)))
    return RouteSpec(
        rel_name=rel.name,
        residual_index=ridx,
        offset=res.reducer_offset,
        hashed=tuple(hashed),
        replicated=tuple(replicated),
        pins=tuple(pins),
        ordinary_excludes=tuple(excludes),
    )


def map_phase(
    plan: SharesSkewPlan, rel: RelationSchema, rows: jnp.ndarray
) -> jnp.ndarray:
    """Full map step for one relation: concat of per-residual destination
    blocks -> [N, total_width] global reducer ids (−1 = not emitted)."""
    specs = build_route_specs(plan, rel)
    blocks = [s.destinations(rows) for s in specs]
    return jnp.concatenate(blocks, axis=1)


def static_route_table(
    plan: SharesSkewPlan, rel: RelationSchema
) -> tuple[tuple, ...]:
    """The plan's routing recipes for one relation as an all-static,
    hashable tuple — the jit-static form consumed by the fused ingest
    kernel (``kernels.ingest_fused``), whose destination math must match
    ``map_phase`` bit-for-bit, column layout included."""
    out = []
    for s in build_route_specs(plan, rel):
        rep = tuple(int(x) for x in s.replica_offsets().tolist())
        out.append((s.offset, s.hashed, rep, s.pins, s.ordinary_excludes))
    return tuple(out)
