"""The naive skew join of Example 1 (Pig/Hive-style, and [24]).

For R(A,B) ⋈ S(B,C) with heavy hitter b: partition the larger relation's
b-tuples across k reducers by hashing the *other* attribute, and broadcast
the smaller relation's b-tuples to all k.  Communication = r + k*s (r >= s).
Non-HH tuples go through an ordinary hash join on B.

This is the baseline SharesSkew beats (2*sqrt(k r s) < r + k*s); implemented
as a host-side cost/load model — benchmarks compare its telemetry with the
executor's measured telemetry under identical data.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .hashing import bucket_np


@dataclasses.dataclass(frozen=True)
class NaiveStats:
    comm_tuples: int
    reducer_loads: np.ndarray  # [k_hh + k_ord]
    k_hh: int
    k_ord: int

    @property
    def max_load(self) -> int:
        return int(self.reducer_loads.max())

    @property
    def load_imbalance(self) -> float:
        return float(self.reducer_loads.max() / self.reducer_loads.mean())


def naive_two_way(
    r_rows: np.ndarray,  # R(A, B)
    s_rows: np.ndarray,  # S(B, C)
    hh_values: np.ndarray,
    k_hh: int,
    k_ord: int,
    seed: int = 0xBEEF,
) -> NaiveStats:
    hh = np.asarray(hh_values, dtype=r_rows.dtype)
    r_is_hh = np.isin(r_rows[:, 1], hh)
    s_is_hh = np.isin(s_rows[:, 0], hh)
    loads = np.zeros(k_hh + k_ord, dtype=np.int64)

    # --- HH block: partition the bigger side, broadcast the smaller --------
    r_hh, s_hh = int(r_is_hh.sum()), int(s_is_hh.sum())
    if r_hh >= s_hh:
        part_col = r_rows[r_is_hh, 0]  # hash A
        np.add.at(loads, bucket_np(part_col, seed, k_hh).astype(np.int64), 1)
        loads[:k_hh] += s_hh  # broadcast S's HH tuples to all k_hh reducers
        comm_hh = r_hh + k_hh * s_hh
    else:
        part_col = s_rows[s_is_hh, 1]  # hash C
        np.add.at(loads, bucket_np(part_col, seed, k_hh).astype(np.int64), 1)
        loads[:k_hh] += r_hh
        comm_hh = s_hh + k_hh * r_hh

    # --- ordinary block: hash join on B -------------------------------------
    for col in (r_rows[~r_is_hh, 1], s_rows[~s_is_hh, 0]):
        b = bucket_np(col, seed + 1, k_ord).astype(np.int64) + k_hh
        np.add.at(loads, b, 1)
    comm_ord = int((~r_is_hh).sum() + (~s_is_hh).sum())

    return NaiveStats(
        comm_tuples=comm_hh + comm_ord,
        reducer_loads=loads,
        k_hh=k_hh,
        k_ord=k_ord,
    )
