"""Reduce-phase primitives: bin emissions by reducer, join locally.

Grouping uses a sort + rank-in-group scatter (static shapes, no host
roundtrip).  The local multiway join is expressed as an einsum over pairwise
match matrices — on TPU this contraction is exactly what the MXU wants, and
the 2-way inner block is what ``repro.kernels.block_join`` implements as a
Pallas kernel (the jnp path here doubles as its oracle at system level).

Join *outputs* are returned as (count, checksum) rather than materialized
tuples: output size is data-dependent (unknowable statically), while count +
an orderless hash-weighted checksum give a complete correctness fingerprint
against the host oracle.  A capacity-bounded materialization is provided for
2-way joins.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.schema import JoinQuery

from .hashing import row_weight_jnp

_EINSUM_LETTERS = "abcdefghij"


def group_by_reducer(
    dests: jnp.ndarray,  # [M] int32 global reducer ids, -1 = dropped
    rows: jnp.ndarray,  # [M, arity]
    num_reducers: int,
    cap: int,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Scatter emissions into per-reducer bins.

    Returns (bins [K, cap, arity], valid [K, cap], loads [K], overflow).
    ``loads`` counts *all* arrivals (pre-capacity) so skew is observable;
    ``overflow`` counts tuples dropped because a bin exceeded cap.
    """
    m = dests.shape[0]
    k = num_reducers
    d = jnp.where(dests >= 0, dests, k).astype(jnp.int32)  # invalid -> bin k
    order = jnp.argsort(d, stable=True)
    ds = d[order]
    rs = rows[order]
    # rank within group: position - first index of this dest value
    first = jnp.searchsorted(ds, ds, side="left")
    rank = jnp.arange(m, dtype=jnp.int32) - first.astype(jnp.int32)
    ok = (ds < k) & (rank < cap)
    # scatter; clamped ids for dropped rows point at a scratch bin
    bid = jnp.where(ok, ds, k)
    rid = jnp.where(ok, rank, 0)
    bins = jnp.zeros((k + 1, cap, rows.shape[1]), dtype=rows.dtype)
    bins = bins.at[bid, rid].set(rs)
    valid = jnp.zeros((k + 1, cap), dtype=bool).at[bid, rid].set(ok)
    loads = jnp.zeros(k + 1, dtype=jnp.int32).at[d].add(1)[:k]
    overflow = jnp.sum((ds < k) & (rank >= cap))
    return bins[:k], valid[:k], loads, overflow


@dataclasses.dataclass(frozen=True)
class LocalJoinSpec:
    """Static join structure: which relation pairs share which columns."""

    rel_names: tuple[str, ...]
    # (rel_i, rel_j, ((col_in_i, col_in_j), ...)) for every linked pair i<j
    links: tuple[tuple[int, int, tuple[tuple[int, int], ...]], ...]

    @classmethod
    def from_query(cls, query: JoinQuery) -> "LocalJoinSpec":
        rels = query.relations
        links = []
        for i in range(len(rels)):
            for j in range(i + 1, len(rels)):
                shared = [a for a in rels[i].attrs if a in rels[j].attrs]
                if shared:
                    links.append(
                        (
                            i,
                            j,
                            tuple(
                                (rels[i].index_of(a), rels[j].index_of(a))
                                for a in shared
                            ),
                        )
                    )
        if len(rels) > len(_EINSUM_LETTERS):
            raise ValueError("joins over >10 relations not supported")
        return cls(tuple(r.name for r in rels), tuple(links))


def _match_matrix(
    bi: jnp.ndarray, vi: jnp.ndarray, bj: jnp.ndarray, vj: jnp.ndarray, cols
) -> jnp.ndarray:
    """Batched pairwise equality: bi [K,ca,arity], bj [K,cb,arity] ->
    [K, ca, cb] bool."""
    m = vi[:, :, None] & vj[:, None, :]
    for ci, cj in cols:
        m &= bi[:, :, ci][:, :, None] == bj[:, :, cj][:, None, :]
    return m


def local_join_count_checksum(
    spec: LocalJoinSpec,
    bins: dict[str, jnp.ndarray],  # name -> [K, cap, arity]
    valids: dict[str, jnp.ndarray],  # name -> [K, cap]
    weight_seed: int = 0x5EED,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-reducer-batched multiway join. Returns (count, checksum) scalars.

    checksum = sum over joined tuples of the product of per-relation tuple
    weights (mod 2^32, reported as uint32) — orderless, matches the oracle.
    """
    n = len(spec.rel_names)
    letters = _EINSUM_LETTERS[:n]
    operands_cnt = []
    subs = []
    for i, j, cols in spec.links:
        name_i, name_j = spec.rel_names[i], spec.rel_names[j]
        m = _match_matrix(
            bins[name_i], valids[name_i], bins[name_j], valids[name_j], cols
        )
        operands_cnt.append(m.astype(jnp.int32))
        subs.append(f"k{letters[i]}{letters[j]}")
    # validity for relations not covered by any link (cross products)
    covered = {i for i, j, _ in spec.links} | {j for _, j, _ in spec.links}
    ones = []
    for i in range(n):
        if i not in covered:
            ones.append(valids[spec.rel_names[i]].astype(jnp.int32))
            subs.append(f"k{letters[i]}")
    expr = ",".join(subs) + "->k"
    count = jnp.einsum(expr, *operands_cnt, *ones)

    # weights folded per relation
    w_ops = []
    w_subs = []
    for i, name in enumerate(spec.rel_names):
        b, v = bins[name], valids[name]
        flat = b.reshape(-1, b.shape[-1])
        w = row_weight_jnp(flat, weight_seed + i).reshape(b.shape[0], b.shape[1])
        w = jnp.where(v, w, 0)  # invalid rows never join; zero is safe
        w_ops.append(w.astype(jnp.uint32))
        w_subs.append(f"k{letters[i]}")
    # uint32 einsum unsupported on some backends; do modular arithmetic via
    # float64-free int32 wraparound: cast through int32 (two's complement wrap)
    expr_w = ",".join(subs + w_subs) + "->k"
    checksum = jnp.einsum(
        expr_w,
        *[o.astype(jnp.int32) for o in operands_cnt],
        *[o.astype(jnp.int32) for o in ones],
        *[w.astype(jnp.int32) for w in w_ops],
    )
    return jnp.sum(count), jnp.sum(checksum).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("spec", "weight_seed"))
def local_join_count_checksum_jit(
    spec: LocalJoinSpec,
    bins: dict[str, jnp.ndarray],
    valids: dict[str, jnp.ndarray],
    weight_seed: int = 0x5EED,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Jit-cached ``local_join_count_checksum`` (``spec`` is hashable and
    static).  Same integer math, so results are bit-identical; the eager
    version stays as the oracle while this one serves latency-critical
    callers (the streaming fused-ingest path, DESIGN.md §7) where per-call
    op-by-op dispatch would dominate the batch budget."""
    return local_join_count_checksum(spec, bins, valids, weight_seed)


def materialize_two_way(
    spec: LocalJoinSpec,
    bins: dict[str, jnp.ndarray],
    valids: dict[str, jnp.ndarray],
    out_cap: int,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """2-way joins only: emit joined rows [out_cap, arity_l + arity_r]
    (zero-padded), their validity mask, and an overflow count."""
    if len(spec.rel_names) != 2:
        raise ValueError("materialize_two_way is for 2-way joins")
    (i, j, cols), = spec.links
    li, lj = spec.rel_names[i], spec.rel_names[j]
    m = _match_matrix(bins[li], valids[li], bins[lj], valids[lj], cols)  # [K,ca,cb]
    k, ca, cb = m.shape
    flat = m.reshape(-1)
    total = flat.shape[0]
    idx = jnp.nonzero(flat, size=out_cap, fill_value=total)[0]
    ok = idx < total
    idx = jnp.where(ok, idx, 0)
    kk = idx // (ca * cb)
    ra = (idx // cb) % ca
    rb = idx % cb
    left = bins[li][kk, ra]
    right = bins[lj][kk, rb]
    rows = jnp.concatenate([left, right], axis=-1)
    rows = jnp.where(ok[:, None], rows, 0)
    overflow = jnp.maximum(jnp.sum(m) - jnp.sum(ok), 0)
    return rows, ok, overflow
