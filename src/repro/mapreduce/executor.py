"""End-to-end SharesSkew join execution on JAX (paper §5.2 stage 4 + reduce).

Two paths:
  * ``run_join`` — single-process: map -> bin-by-reducer -> einsum join,
    entirely under jit with static shapes (logical reducers tiled on the
    local device; the paper's Reduce-task-hosting-many-reducers).
  * ``repro.mapreduce.shuffle.run_distributed`` — shard_map + all_to_all over
    a device mesh axis (the real shuffle), same reduce phase per device.

Results carry communication and per-reducer-load telemetry so benchmarks can
reproduce the paper's Figures 1-3 (shuffle cost, load skew).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.planner import ResidualPlan, SharesSkewPlan
from repro.core.schema import JoinQuery

from .keys import map_phase
from .local_join import LocalJoinSpec, group_by_reducer, local_join_count_checksum


@dataclasses.dataclass(frozen=True)
class JoinResult:
    count: int
    checksum: int
    comm_tuples: dict[str, int]  # tuples shipped mapper->reducer per relation
    reducer_loads: np.ndarray  # [K] total arrivals per reducer (all relations)
    overflow: int  # tuples dropped by capacity (must be 0 for valid runs)

    @property
    def total_comm(self) -> int:
        return int(sum(self.comm_tuples.values()))

    @property
    def max_load(self) -> int:
        return int(self.reducer_loads.max()) if self.reducer_loads.size else 0

    @property
    def load_imbalance(self) -> float:
        """max / mean reducer load — the skew the paper fights."""
        loads = self.reducer_loads
        if loads.size == 0 or loads.mean() == 0:
            return 0.0
        return float(loads.max() / loads.mean())


def _bin_cap(plan: SharesSkewPlan, cap_factor: float) -> int:
    cap = int(math.ceil(plan.q * cap_factor)) + 8
    return max(16, cap)


def build_pipeline(
    query: JoinQuery, plan: SharesSkewPlan, cap: int
):
    """Build the jitted map+reduce pipeline (static over plan/query/cap)."""
    spec = LocalJoinSpec.from_query(query)
    k = plan.total_reducers

    def pipeline(rows_by_rel: dict[str, jnp.ndarray]):
        bins, valids = {}, {}
        loads_total = jnp.zeros(k, dtype=jnp.int32)
        comm = {}
        overflow = jnp.int32(0)
        for rel in query.relations:
            rows = rows_by_rel[rel.name]
            dest = map_phase(plan, rel, rows)  # [N, W]
            n, w = dest.shape
            flat_dest = dest.reshape(-1)
            flat_rows = jnp.broadcast_to(
                rows[:, None, :], (n, w, rows.shape[1])
            ).reshape(-1, rows.shape[1])
            b, v, loads, ov = group_by_reducer(flat_dest, flat_rows, k, cap)
            bins[rel.name], valids[rel.name] = b, v
            loads_total = loads_total + loads
            comm[rel.name] = jnp.sum(flat_dest >= 0)
            overflow = overflow + ov
        count, checksum = local_join_count_checksum(spec, bins, valids)
        return count, checksum, comm, loads_total, overflow

    return jax.jit(pipeline), spec


def run_join(
    query: JoinQuery,
    data: dict[str, np.ndarray],
    plan: SharesSkewPlan,
    cap_factor: float = 3.0,
) -> JoinResult:
    """Execute the plan single-process. ``cap_factor`` scales the per-reducer
    bin capacity above the expected load q (hash variance headroom)."""
    if not plan.residuals:  # some relation is empty -> join is empty
        return JoinResult(
            count=0,
            checksum=0,
            comm_tuples={r.name: 0 for r in query.relations},
            reducer_loads=np.zeros(0, dtype=np.int32),
            overflow=0,
        )
    cap = _bin_cap(plan, cap_factor)
    pipe, _ = build_pipeline(query, plan, cap)
    rows = {
        name: jnp.asarray(np.asarray(arr), dtype=jnp.int32)
        for name, arr in data.items()
    }
    count, checksum, comm, loads, overflow = pipe(rows)
    return JoinResult(
        count=int(count),
        checksum=int(np.uint32(checksum)),
        comm_tuples={n: int(c) for n, c in comm.items()},
        reducer_loads=np.asarray(loads),
        overflow=int(overflow),
    )


def measure_loads(
    query: JoinQuery, data: dict[str, np.ndarray], plan: SharesSkewPlan
) -> JoinResult:
    """Map phase only: routes every tuple and tallies per-reducer arrivals
    and shuffle volume WITHOUT executing the reduce-side join.  Used to
    profile load skew where actually materializing the reducers would be
    prohibitively large (e.g. plain Shares on heavily skewed data)."""
    k = plan.total_reducers
    if k == 0:
        return JoinResult(0, 0, {r.name: 0 for r in query.relations},
                          np.zeros(0, np.int32), 0)
    loads = np.zeros(k, dtype=np.int64)
    comm = {}
    for rel in query.relations:
        rows = jnp.asarray(np.asarray(data[rel.name]), dtype=jnp.int32)
        dest = np.asarray(map_phase(plan, rel, rows)).reshape(-1)
        valid = dest >= 0
        loads += np.bincount(dest[valid], minlength=k)
        comm[rel.name] = int(valid.sum())
    return JoinResult(
        count=-1,  # join not executed
        checksum=0,
        comm_tuples=comm,
        reducer_loads=np.asarray(loads),
        overflow=0,
    )


def predicted_comm(plan: SharesSkewPlan) -> dict[str, int]:
    """Exact communication the executor will produce: per relation, the sum
    over residuals of relevant_size x replication (integer shares)."""
    out: dict[str, int] = {r.name: 0 for r in plan.query.relations}
    for res in plan.residuals:
        for rel in plan.query.relations:
            out[rel.name] += res.sizes[rel.name] * res.int_replication(rel.attrs)
    return out


def run_join_speculative(
    query: JoinQuery,
    data: dict[str, np.ndarray],
    plan: SharesSkewPlan,
    cap_factor: float = 3.0,
    n_shards: int = 4,
    max_workers: int = 4,
    speculate_after: float = 3.0,
    max_attempts: int = 3,
    injector=None,
    deadline_s: float | None = None,
    checksum_results: bool = True,
) -> JoinResult:
    """run_join with the reduce phase over-decomposed into reducer shards
    executed under speculative re-execution (straggler mitigation,
    DESIGN.md §5).  Each shard re-runs the jitted pipeline restricted to a
    block of residual joins; results combine associatively (counts and
    checksums add mod 2^32), so duplicate completions are idempotent.

    Shard failures are retried up to ``max_attempts`` submissions; a shard
    that still fails raises here with its error — a partial join result is
    never returned silently.  ``injector`` (``repro.testing.faults``)
    deterministically faults chosen attempts to exercise those paths.

    ``deadline_s`` arms the shard-level failure detector: an attempt silent
    past the deadline is declared failed and re-issued (DESIGN.md §5
    detection).  ``checksum_results`` (on by default) seals every shard
    result in a worker-side CRC32 envelope verified on receipt, so a
    corrupted result (``corrupt_result`` fault, or a real in-transit flip)
    becomes a retried attempt — never a wrong join answer."""
    from .straggler import run_with_speculation

    residuals = plan.residuals
    if not residuals:
        return run_join(query, data, plan, cap_factor)
    n_shards = max(1, min(n_shards, len(residuals)))
    blocks = np.array_split(np.arange(len(residuals)), n_shards)

    def make_shard(idx_block):
        # a sub-plan containing only this block's residual joins
        subs = tuple(residuals[i] for i in idx_block)
        offset = 0
        rebased = []
        for r in subs:
            rebased.append(
                ResidualPlan(r.combo, r.sizes, r.k_budget, r.solution, offset)
            )
            offset += r.num_reducers
        sub_plan = SharesSkewPlan(plan.query, plan.q, plan.hh_values, tuple(rebased))

        def shard_fn():
            return run_join(query, data, sub_plan, cap_factor)

        return shard_fn

    outcomes = run_with_speculation(
        [make_shard(b) for b in blocks],
        max_workers=max_workers,
        speculate_after=speculate_after,
        max_attempts=max_attempts,
        injector=injector,
        deadline_s=deadline_s,
        checksum_results=checksum_results,
    )
    if injector is not None:
        injector.resolve(outcomes)
    failed = [o for o in outcomes if o.error is not None]
    if failed:
        raise RuntimeError(
            f"{len(failed)} reduce shard(s) failed after "
            f"{max_attempts} attempts: "
            + "; ".join(f"shard {o.shard_id}: {o.error}" for o in failed)
        )
    results: list[JoinResult] = [o.result for o in outcomes]
    return JoinResult(
        count=sum(r.count for r in results),
        checksum=int(np.uint32(sum(np.uint32(r.checksum) for r in results))),
        comm_tuples={
            rel.name: sum(r.comm_tuples[rel.name] for r in results)
            for rel in query.relations
        },
        reducer_loads=np.concatenate([r.reducer_loads for r in results]),
        overflow=sum(r.overflow for r in results),
    )
