"""JAX MapReduce join engine: map-phase key generation, shuffle, reduce."""
from .executor import (JoinResult, build_pipeline, measure_loads,
                        predicted_comm, run_join, run_join_speculative)
from .keys import RouteSpec, build_route_specs, map_phase
from .local_join import (
    LocalJoinSpec,
    group_by_reducer,
    local_join_count_checksum,
    materialize_two_way,
)
from .naive import NaiveStats, naive_two_way
from .oracle import oracle_join
from .shuffle import run_distributed

__all__ = [
    "JoinResult",
    "LocalJoinSpec",
    "NaiveStats",
    "RouteSpec",
    "build_pipeline",
    "build_route_specs",
    "group_by_reducer",
    "local_join_count_checksum",
    "map_phase",
    "materialize_two_way",
    "naive_two_way",
    "oracle_join",
    "measure_loads",
    "predicted_comm",
    "run_distributed",
    "run_join_speculative",
    "run_join",
]
