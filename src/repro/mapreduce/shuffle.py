"""Distributed shuffle: shard_map + all_to_all over a mesh axis.

This is the MapReduce shuffle mapped onto the TPU fabric (DESIGN.md §2):
each device maps its input shard, packs per-destination-device send buffers
(static capacity — the paper's reducer bound q gives the budget), exchanges
them with a single all_to_all, then bins received tuples into its local
block of reducers and joins.  Reducer ids are block-partitioned over the
axis: device d owns global reducers [d*g, (d+1)*g).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

try:  # newer jax exports shard_map at top level
    from jax import shard_map as _shard_map
except ImportError:  # older jax keeps it in experimental
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-compat shard_map: the replication-check kwarg was renamed
    check_rep -> check_vma across jax releases."""
    try:
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    except TypeError:
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
        )

from repro.core.planner import SharesSkewPlan
from repro.core.schema import JoinQuery

from .executor import JoinResult, _bin_cap, predicted_comm
from .keys import map_phase
from .local_join import LocalJoinSpec, group_by_reducer, local_join_count_checksum


def _pad_shard(arr: np.ndarray, d: int) -> tuple[np.ndarray, np.ndarray]:
    """Pad leading dim to a multiple of d; returns (padded, valid_mask)."""
    n = arr.shape[0]
    n_pad = int(math.ceil(max(n, 1) / d) * d)
    out = np.zeros((n_pad,) + arr.shape[1:], dtype=arr.dtype)
    out[:n] = arr
    mask = np.zeros(n_pad, dtype=bool)
    mask[:n] = True
    return out, mask


def run_distributed(
    query: JoinQuery,
    data: dict[str, np.ndarray],
    plan: SharesSkewPlan,
    mesh: Mesh | None = None,
    axis_name: str = "shuffle",
    cap_factor: float = 3.0,
    route_cap_factor: float = 3.0,
) -> JoinResult:
    if not plan.residuals:  # some relation is empty -> join is empty
        return JoinResult(
            count=0,
            checksum=0,
            comm_tuples={r.name: 0 for r in query.relations},
            reducer_loads=np.zeros(0, dtype=np.int32),
            overflow=0,
        )
    if mesh is None:
        devs = np.array(jax.devices())
        mesh = Mesh(devs, (axis_name,))
    d = mesh.shape[axis_name]
    k = plan.total_reducers
    g = int(math.ceil(k / d))  # reducers per device
    k_pad = g * d
    cap = _bin_cap(plan, cap_factor)
    spec = LocalJoinSpec.from_query(query)

    pred = predicted_comm(plan)
    route_caps = {
        name: max(32, int(math.ceil(pred[name] / (d * d) * route_cap_factor)) + 16)
        for name in pred
    }

    rel_order = [r.name for r in query.relations]
    padded, masks = {}, {}
    for name in rel_order:
        arr = np.asarray(data[name], dtype=np.int32)
        padded[name], masks[name] = _pad_shard(arr, d)

    def stage(rows_list, mask_list):
        my_dev = jax.lax.axis_index(axis_name)
        bins, valids = {}, {}
        loads_local = jnp.zeros(g, dtype=jnp.int32)
        comm = []
        overflow = jnp.int32(0)
        for rel, rows, rowmask in zip(query.relations, rows_list, mask_list):
            rcap = route_caps[rel.name]
            dest = map_phase(plan, rel, rows)  # [n_loc, W]
            dest = jnp.where(rowmask[:, None], dest, jnp.int32(-1))
            n, w = dest.shape
            flat_dest = dest.reshape(-1)
            flat_rows = jnp.broadcast_to(
                rows[:, None, :], (n, w, rows.shape[1])
            ).reshape(-1, rows.shape[1])
            comm.append(jnp.sum(flat_dest >= 0))
            # ---- pack per-destination-device send buffers ----
            dev_ids = jnp.where(flat_dest >= 0, flat_dest // g, jnp.int32(-1))
            payload = jnp.concatenate([flat_rows, flat_dest[:, None]], axis=1)
            send, send_ok, _, ov1 = group_by_reducer(dev_ids, payload, d, rcap)
            # ---- the shuffle ----
            recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0)
            recv_ok = jax.lax.all_to_all(
                send_ok.astype(jnp.int32), axis_name, split_axis=0, concat_axis=0
            ).astype(bool)
            rr = recv.reshape(-1, payload.shape[1])
            ok = recv_ok.reshape(-1)
            gdest = rr[:, -1]
            local = jnp.where(ok, gdest - my_dev * g, jnp.int32(-1))
            b, v, loads, ov2 = group_by_reducer(local, rr[:, :-1], g, cap)
            bins[rel.name], valids[rel.name] = b, v
            loads_local = loads_local + loads
            overflow = overflow + ov1 + ov2
        count, checksum = local_join_count_checksum(spec, bins, valids)
        count = jax.lax.psum(count, axis_name)
        checksum = jax.lax.psum(checksum.astype(jnp.int32), axis_name)
        comm = [jax.lax.psum(c, axis_name) for c in comm]
        overflow = jax.lax.psum(overflow, axis_name)
        return count, checksum, jnp.stack(comm), loads_local, overflow

    in_row_specs = [P(axis_name) for _ in rel_order]
    in_mask_specs = [P(axis_name) for _ in rel_order]
    fn = shard_map(
        stage,
        mesh=mesh,
        in_specs=(tuple(in_row_specs), tuple(in_mask_specs)),
        out_specs=(P(), P(), P(), P(axis_name), P()),
        check_vma=False,
    )
    rows_in = tuple(jnp.asarray(padded[nm]) for nm in rel_order)
    masks_in = tuple(jnp.asarray(masks[nm]) for nm in rel_order)
    count, checksum, comm, loads, overflow = jax.jit(fn)(rows_in, masks_in)
    loads = np.asarray(loads)[:k]
    return JoinResult(
        count=int(count),
        checksum=int(np.uint32(np.int64(checksum) & 0xFFFFFFFF)),
        comm_tuples={nm: int(c) for nm, c in zip(rel_order, np.asarray(comm))},
        reducer_loads=loads,
        overflow=int(overflow),
    )
