"""Straggler mitigation: speculative re-execution of slow reduce shards.

MapReduce-native fault handling (DESIGN.md §5): the reduce phase is split
into independent shards (blocks of reducers).  A shard that runs slower
than ``speculate_after`` x the median completed-shard time gets a backup
execution; the first result wins.  Because shards are deterministic pure
functions, duplicate completion is harmless (results are idempotent).

Failures are first-class (DESIGN.md §5/§8): a shard attempt that raises is
retried up to ``max_attempts`` total submissions; a shard that exhausts
its attempts ends with ``ShardOutcome.error`` set — an explicit report the
caller must handle, never a silent loss.  Exactly ONE ``ShardOutcome`` is
produced per shard, always: a terminal error recorded while a sibling
attempt is still in flight is held pending and materialized once the last
sibling resolves (or when the pool drains), so no ordering of completions,
cancellations, or drops can make a shard vanish from the result list.

Two further seams harden the runner against real-cluster failure modes:

  * ``deadline_s`` — a heartbeat deadline on in-flight attempts: an
    attempt that has neither completed nor failed within the deadline is
    *declared* failed (the zombie worker is fenced: its eventual result
    is ignored once the shard resolves another way) and the attempt
    budget drives a re-submission.  This is the shard-level half of the
    failure detector; ``FailureDetector`` below is the host-level half
    used by the streaming engine (DESIGN.md §5 detection stage).
  * ``checksum_results=True`` — workers seal each result in a CRC32
    envelope *before* it crosses the thread boundary; the collector
    verifies on receipt.  A corrupted result (``repro.testing.faults``
    kind ``corrupt_result``, or a real bit-flip in transit) is detected,
    counted as a failed attempt, and retried — never returned.

A ``repro.testing.faults`` ``FaultInjector`` can wrap each attempt to
exercise exactly these paths deterministically (drop / duplicate / delay /
preempt / corrupt_result).

On a real pod the backup lands on a different host; here workers are
threads, which is the same control plane with a process-local executor.
"""
from __future__ import annotations

import dataclasses
import pickle
import time
import zlib
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Callable, Hashable, Sequence


class ChecksumMismatch(RuntimeError):
    """A shard result failed CRC verification on receipt (corrupt in
    transit).  Treated exactly like a failed attempt: retried, and
    terminal after ``max_attempts`` — a corrupt result is never returned."""


@dataclasses.dataclass(frozen=True)
class SealedResult:
    """A shard result sealed by the worker before crossing the thread
    boundary: CRC32 over the pickled payload, verified by the collector."""

    payload: bytes
    crc: int

    @classmethod
    def seal(cls, obj: object) -> "SealedResult":
        payload = pickle.dumps(obj)
        return cls(payload=payload, crc=zlib.crc32(payload))

    def unseal(self) -> object:
        if zlib.crc32(self.payload) != self.crc:
            raise ChecksumMismatch(
                f"shard result CRC mismatch: expected {self.crc:#010x}, "
                f"payload hashes to {zlib.crc32(self.payload):#010x}"
            )
        return pickle.loads(self.payload)


@dataclasses.dataclass
class ShardOutcome:
    shard_id: int
    result: object  # None iff the shard failed terminally
    attempts: int  # total submissions (initial + retries + backups)
    speculated: bool
    elapsed_s: float  # the WINNING attempt's own latency (not first-submit age)
    error: str | None = None  # terminal failure after retries, else None


class FailureDetector:
    """Deadline-based failure detection over member heartbeats.

    The host-level half of DESIGN.md §5 detection: members (hosts, shards)
    record heartbeats at ``now``; ``overdue(now)`` returns every registered
    member whose last heartbeat is ``deadline`` or more behind ``now``.
    Time is whatever monotone clock the caller uses — wall seconds for the
    shard runner, *batch indices* for the streaming engine (which makes
    detection deterministic under test).
    """

    def __init__(self, deadline: float):
        if deadline <= 0:
            raise ValueError("deadline must be > 0")
        self.deadline = float(deadline)
        self._last: dict[Hashable, float] = {}

    def heartbeat(self, member: Hashable, now: float) -> None:
        self._last[member] = float(now)

    def deregister(self, member: Hashable) -> None:
        """Forget a member (declared dead or decommissioned)."""
        self._last.pop(member, None)

    @property
    def members(self) -> tuple[Hashable, ...]:
        return tuple(self._last)

    def overdue(self, now: float) -> list[Hashable]:
        """Members whose heartbeat age >= deadline, oldest-lag first."""
        late = [
            (now - t, m) for m, t in self._last.items()
            if now - t >= self.deadline
        ]
        return [m for _, m in sorted(late, key=lambda p: (-p[0], str(p[1])))]


def run_with_speculation(
    shard_fns: Sequence[Callable[[], object]],
    max_workers: int = 4,
    speculate_after: float = 3.0,
    poll_interval_s: float = 0.01,
    min_completed_before_speculation: int = 2,
    max_attempts: int = 3,
    injector=None,
    deadline_s: float | None = None,
    checksum_results: bool = False,
    metrics=None,
) -> list[ShardOutcome]:
    """Run every shard; re-issue stragglers and failed attempts; return
    exactly one outcome per shard.  ``injector`` (``repro.testing.faults``)
    wraps each attempt for deterministic fault injection; ``max_attempts``
    bounds total submissions per shard, after which the outcome carries
    ``error``.  ``deadline_s`` declares an in-flight attempt failed after
    that many seconds (the zombie is fenced, not killed — threads cannot
    be).  ``checksum_results`` seals results in a worker-side CRC envelope
    verified on receipt; a mismatch counts as a failed attempt.

    ``metrics`` (DESIGN.md §10): anything with ``histogram(name,
    **labels)`` / ``counter(name, **labels)`` — an ``obs.MetricsRegistry``
    or the engine's ``Observability`` facade.  Per-attempt latencies land
    in ``straggler_attempt_seconds`` (label ``outcome=ok|error``) and the
    mitigation events in ``straggler_*_total`` counters.  The instruments
    lock internally, so recording is safe from this runner's collector
    even while worker threads are live."""
    outcomes: dict[int, ShardOutcome] = {}

    def _count(name: str, **labels) -> None:
        if metrics is not None:
            metrics.counter(name, **labels).inc()

    def _observe(seconds: float, **labels) -> None:
        if metrics is not None:
            metrics.histogram("straggler_attempt_seconds", **labels).observe(
                seconds
            )

    def wrapped(i: int, attempt: int) -> Callable[[], object]:
        fn = shard_fns[i]
        if checksum_results:
            inner = fn

            def sealed_fn(inner=inner):
                return SealedResult.seal(inner())

            fn = sealed_fn
        return injector.wrap(i, attempt, fn) if injector is not None else fn

    n = len(shard_fns)
    pending_error: dict[int, str] = {}  # terminal error awaiting last sibling
    submitted: dict[int, int] = {i: 0 for i in range(n)}
    inflight: dict[int, int] = {i: 0 for i in range(n)}
    speculated: set[int] = set()
    declared_dead: set[Future] = set()  # deadline-fenced zombies
    futures: dict[Future, int] = {}
    attempt_start: dict[Future, float] = {}  # per-attempt submit time (S1 fix)

    def record_terminal(i: int, now: float) -> None:
        outcomes[i] = ShardOutcome(
            shard_id=i,
            result=None,
            attempts=submitted[i],
            speculated=i in speculated,
            elapsed_s=0.0,
            error=pending_error.get(i, "no attempt produced an outcome"),
        )

    with ThreadPoolExecutor(max_workers=max_workers) as pool:
        def submit(i: int) -> None:
            submitted[i] += 1
            inflight[i] += 1
            f = pool.submit(wrapped(i, submitted[i]))
            futures[f] = i
            attempt_start[f] = time.monotonic()

        for i in range(n):
            copies = 1 + (
                injector.extra_initial_attempts(i) if injector is not None else 0
            )
            for _ in range(copies):
                submit(i)
        durations: list[float] = []

        def attempt_failed(i: int, msg: str, now: float) -> None:
            """One attempt of shard ``i`` is gone (exception, checksum
            mismatch, or deadline): resubmit if budget remains, otherwise
            hold the terminal error and materialize the outcome once no
            sibling is left in flight."""
            if i in outcomes:
                return
            if submitted[i] < max_attempts:
                _count("straggler_retries_total")
                submit(i)
                return
            pending_error.setdefault(i, msg)
            if inflight[i] == 0:
                record_terminal(i, now)
                _count("straggler_shards_failed_total")

        while futures:
            done, _ = wait(
                list(futures), timeout=poll_interval_s, return_when=FIRST_COMPLETED
            )
            now = time.monotonic()
            for f in done:
                i = futures.pop(f)
                started = attempt_start.pop(f)
                inflight[i] -= 1
                if f in declared_dead:
                    declared_dead.discard(f)
                    continue  # fenced: the shard already resolved another way
                if i in outcomes:
                    continue  # backup finished after primary; ignore
                exc = f.exception()
                if exc is not None:
                    _observe(now - started, outcome="error")
                    attempt_failed(i, f"{type(exc).__name__}: {exc}", now)
                    continue
                result = f.result()
                if checksum_results:
                    try:
                        result = result.unseal()
                    except ChecksumMismatch as cm:
                        _observe(now - started, outcome="error")
                        _count("straggler_checksum_mismatches_total")
                        attempt_failed(i, f"ChecksumMismatch: {cm}", now)
                        continue
                elapsed = now - started  # this attempt's own latency
                _observe(elapsed, outcome="ok")
                outcomes[i] = ShardOutcome(
                    shard_id=i,
                    result=result,
                    attempts=submitted[i],
                    speculated=i in speculated,
                    elapsed_s=elapsed,
                )
                durations.append(elapsed)
            # deadline detection: fence in-flight attempts that went silent
            if deadline_s is not None:
                for f, i in list(futures.items()):
                    if f in declared_dead or i in outcomes:
                        continue
                    if now - attempt_start[f] > deadline_s:
                        declared_dead.add(f)
                        inflight[i] -= 1
                        _count("straggler_deadline_fences_total")
                        attempt_failed(
                            i,
                            f"deadline: attempt silent for > {deadline_s:g}s",
                            now,
                        )
            # speculation: compare running shards against median finished time
            if len(durations) >= min_completed_before_speculation:
                med = sorted(durations)[len(durations) // 2]
                for f, i in list(futures.items()):
                    if i in outcomes or i in speculated or f in declared_dead:
                        continue
                    if now - attempt_start[f] > speculate_after * max(med, 1e-4):
                        if submitted[i] >= max_attempts:
                            continue  # attempt budget exhausted
                        speculated.add(i)
                        _count("straggler_speculated_total")
                        submit(i)
            # drop futures whose shard already completed via another attempt
            for f, i in list(futures.items()):
                if i in outcomes and f.done():
                    futures.pop(f)
                    attempt_start.pop(f, None)
                    declared_dead.discard(f)
                    inflight[i] -= 1
    # the pool has drained: every shard must have resolved.  Materialize any
    # terminal error whose last sibling was dropped/cancelled without
    # reaching the loop above — one ShardOutcome per shard, always.
    now = time.monotonic()
    for i in range(n):
        if i not in outcomes:
            record_terminal(i, now)
            _count("straggler_shards_failed_total")
    assert len(outcomes) == n, "straggler runner lost a shard outcome"
    return [outcomes[i] for i in sorted(outcomes)]
