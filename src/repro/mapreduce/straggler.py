"""Straggler mitigation: speculative re-execution of slow reduce shards.

MapReduce-native fault handling (DESIGN.md §5): the reduce phase is split
into independent shards (blocks of reducers).  A shard that runs slower
than ``speculate_after`` x the median completed-shard time gets a backup
execution; the first result wins.  Because shards are deterministic pure
functions, duplicate completion is harmless (results are idempotent).

On a real pod the backup lands on a different host; here workers are
threads, which is the same control plane with a process-local executor.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Callable, Sequence


@dataclasses.dataclass
class ShardOutcome:
    shard_id: int
    result: object
    attempts: int
    speculated: bool
    elapsed_s: float


def run_with_speculation(
    shard_fns: Sequence[Callable[[], object]],
    max_workers: int = 4,
    speculate_after: float = 3.0,
    poll_interval_s: float = 0.01,
    min_completed_before_speculation: int = 2,
) -> list[ShardOutcome]:
    """Run every shard; re-issue stragglers; return per-shard outcomes."""
    outcomes: dict[int, ShardOutcome] = {}
    lock = threading.Lock()

    with ThreadPoolExecutor(max_workers=max_workers) as pool:
        start = {i: time.monotonic() for i in range(len(shard_fns))}
        attempts: dict[int, int] = {i: 1 for i in range(len(shard_fns))}
        speculated: set[int] = set()
        futures: dict[Future, int] = {
            pool.submit(fn): i for i, fn in enumerate(shard_fns)
        }
        durations: list[float] = []

        while futures:
            done, _ = wait(list(futures), timeout=poll_interval_s, return_when=FIRST_COMPLETED)
            now = time.monotonic()
            for f in done:
                i = futures.pop(f)
                if i in outcomes:
                    continue  # backup finished after primary; ignore
                elapsed = now - start[i]
                with lock:
                    outcomes[i] = ShardOutcome(
                        shard_id=i,
                        result=f.result(),
                        attempts=attempts[i],
                        speculated=i in speculated,
                        elapsed_s=elapsed,
                    )
                    durations.append(elapsed)
            # speculation: compare running shards against median finished time
            if len(durations) >= min_completed_before_speculation:
                med = sorted(durations)[len(durations) // 2]
                for f, i in list(futures.items()):
                    if i in outcomes or i in speculated:
                        continue
                    if now - start[i] > speculate_after * max(med, 1e-4):
                        speculated.add(i)
                        attempts[i] += 1
                        futures[pool.submit(shard_fns[i])] = i
            # drop futures whose shard already completed via another attempt
            for f, i in list(futures.items()):
                if i in outcomes and f.done():
                    futures.pop(f)
    return [outcomes[i] for i in sorted(outcomes)]
