"""Straggler mitigation: speculative re-execution of slow reduce shards.

MapReduce-native fault handling (DESIGN.md §5): the reduce phase is split
into independent shards (blocks of reducers).  A shard that runs slower
than ``speculate_after`` x the median completed-shard time gets a backup
execution; the first result wins.  Because shards are deterministic pure
functions, duplicate completion is harmless (results are idempotent).

Failures are first-class (DESIGN.md §8): a shard attempt that raises is
retried up to ``max_attempts`` total submissions; a shard that exhausts
its attempts ends with ``ShardOutcome.error`` set — an explicit report the
caller must handle, never a silent loss.  A ``repro.testing.faults``
``FaultInjector`` can wrap each attempt to exercise exactly these paths
deterministically (drop / duplicate / delay / preempt).

On a real pod the backup lands on a different host; here workers are
threads, which is the same control plane with a process-local executor.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Callable, Sequence


@dataclasses.dataclass
class ShardOutcome:
    shard_id: int
    result: object  # None iff the shard failed terminally
    attempts: int  # total submissions (initial + retries + backups)
    speculated: bool
    elapsed_s: float
    error: str | None = None  # terminal failure after retries, else None


def run_with_speculation(
    shard_fns: Sequence[Callable[[], object]],
    max_workers: int = 4,
    speculate_after: float = 3.0,
    poll_interval_s: float = 0.01,
    min_completed_before_speculation: int = 2,
    max_attempts: int = 3,
    injector=None,
) -> list[ShardOutcome]:
    """Run every shard; re-issue stragglers and failed attempts; return one
    outcome per shard.  ``injector`` (``repro.testing.faults``) wraps each
    attempt for deterministic fault injection; ``max_attempts`` bounds total
    submissions per shard, after which the outcome carries ``error``."""
    outcomes: dict[int, ShardOutcome] = {}
    lock = threading.Lock()

    def wrapped(i: int, attempt: int) -> Callable[[], object]:
        fn = shard_fns[i]
        return injector.wrap(i, attempt, fn) if injector is not None else fn

    with ThreadPoolExecutor(max_workers=max_workers) as pool:
        start = {i: time.monotonic() for i in range(len(shard_fns))}
        submitted: dict[int, int] = {i: 0 for i in range(len(shard_fns))}
        speculated: set[int] = set()
        futures: dict[Future, int] = {}
        for i in range(len(shard_fns)):
            copies = 1 + (
                injector.extra_initial_attempts(i) if injector is not None else 0
            )
            for _ in range(copies):
                submitted[i] += 1
                futures[pool.submit(wrapped(i, submitted[i]))] = i
        durations: list[float] = []

        while futures:
            done, _ = wait(
                list(futures), timeout=poll_interval_s, return_when=FIRST_COMPLETED
            )
            now = time.monotonic()
            for f in done:
                i = futures.pop(f)
                if i in outcomes:
                    continue  # backup finished after primary; ignore
                exc = f.exception()
                if exc is not None:
                    if submitted[i] < max_attempts:
                        submitted[i] += 1
                        futures[pool.submit(wrapped(i, submitted[i]))] = i
                    elif not any(j == i for j in futures.values()):
                        # out of attempts and no sibling in flight: report
                        with lock:
                            outcomes[i] = ShardOutcome(
                                shard_id=i,
                                result=None,
                                attempts=submitted[i],
                                speculated=i in speculated,
                                elapsed_s=now - start[i],
                                error=f"{type(exc).__name__}: {exc}",
                            )
                    continue
                elapsed = now - start[i]
                with lock:
                    outcomes[i] = ShardOutcome(
                        shard_id=i,
                        result=f.result(),
                        attempts=submitted[i],
                        speculated=i in speculated,
                        elapsed_s=elapsed,
                    )
                    durations.append(elapsed)
            # speculation: compare running shards against median finished time
            if len(durations) >= min_completed_before_speculation:
                med = sorted(durations)[len(durations) // 2]
                for f, i in list(futures.items()):
                    if i in outcomes or i in speculated:
                        continue
                    if now - start[i] > speculate_after * max(med, 1e-4):
                        if submitted[i] >= max_attempts:
                            continue  # attempt budget exhausted
                        speculated.add(i)
                        submitted[i] += 1
                        futures[pool.submit(wrapped(i, submitted[i]))] = i
            # drop futures whose shard already completed via another attempt
            for f, i in list(futures.items()):
                if i in outcomes and f.done():
                    futures.pop(f)
    return [outcomes[i] for i in sorted(outcomes)]
