"""Host-side reference oracle: exact multiway join via hash merges (numpy).

Computes (count, checksum, optionally materialized rows) for any JoinQuery.
The checksum uses the same per-relation tuple weights as the device path
(``hashing.row_weight_np``) summed over joined combinations mod 2^32, so
device results can be compared bit-for-bit.
"""
from __future__ import annotations

from collections import defaultdict

import numpy as np

from repro.core.schema import JoinQuery

from .hashing import row_weight_np


def _join_two(
    left_rows: np.ndarray,
    left_attrs: list[str],
    left_w: np.ndarray,
    right_rows: np.ndarray,
    right_attrs: list[str],
    right_w: np.ndarray,
) -> tuple[np.ndarray, list[str], np.ndarray]:
    shared = [a for a in left_attrs if a in right_attrs]
    li = [left_attrs.index(a) for a in shared]
    ri = [right_attrs.index(a) for a in shared]
    buckets: dict[tuple, list[int]] = defaultdict(list)
    for j in range(right_rows.shape[0]):
        buckets[tuple(right_rows[j, ri])].append(j)
    out_left, out_right = [], []
    for i in range(left_rows.shape[0]):
        key = tuple(left_rows[i, li])
        for j in buckets.get(key, ()):
            out_left.append(i)
            out_right.append(j)
    keep = [a for a in right_attrs if a not in shared]
    ki = [right_attrs.index(a) for a in keep]
    if out_left:
        l_idx = np.asarray(out_left)
        r_idx = np.asarray(out_right)
        rows = np.concatenate(
            [left_rows[l_idx], right_rows[r_idx][:, ki]], axis=1
        )
        w = (left_w[l_idx].astype(np.uint64) * right_w[r_idx].astype(np.uint64)) & 0xFFFFFFFF
    else:
        rows = np.zeros((0, left_rows.shape[1] + len(keep)), dtype=left_rows.dtype)
        w = np.zeros(0, dtype=np.uint64)
    return rows, left_attrs + keep, w.astype(np.uint32)


def oracle_join(
    query: JoinQuery,
    data: dict[str, np.ndarray],
    weight_seed: int = 0x5EED,
) -> tuple[int, int, np.ndarray, list[str]]:
    """Returns (count, checksum_uint32, rows, attr_order).

    checksum = sum over join results of prod_i weight_i(tuple_i) mod 2^32 —
    identical to the device computation (weights multiply in uint32 wrap
    because all intermediate weights stay < 2^32 via masking each step;
    the device multiplies in int32 two's complement which matches mod 2^32).
    """
    rels = query.relations
    rows = np.asarray(data[rels[0].name], dtype=np.int64)
    attrs = list(rels[0].attrs)
    w = row_weight_np(rows, weight_seed + 0).astype(np.uint32)
    for i, rel in enumerate(rels[1:], start=1):
        r = np.asarray(data[rel.name], dtype=np.int64)
        rw = row_weight_np(r, weight_seed + i).astype(np.uint32)
        rows, attrs, w = _join_two(rows, attrs, w, r, list(rel.attrs), rw)
    count = rows.shape[0]
    checksum = int(np.sum(w.astype(np.uint64)) & 0xFFFFFFFF)
    return count, checksum, rows, attrs
