"""Deterministic 32-bit mixing hashes shared by mapper key-gen and oracle.

The Shares algorithm requires one independent hash function per (residual
join, attribute) pair, identical across relations (§3: "independently
chosen random hash functions h_i, one for each attribute").  We derive a
32-bit seed from (residual_index, attribute) and use a murmur3-style
finalizer — implemented identically in numpy (planning/oracle) and jnp
(mapper), so host and device agree bit-for-bit.
"""
from __future__ import annotations

import zlib

import jax.numpy as jnp
import numpy as np


def attr_seed(residual_index: int, attr: str) -> int:
    return zlib.crc32(f"{residual_index}/{attr}".encode()) & 0xFFFFFFFF


def mix32_np(x: np.ndarray, seed: int) -> np.ndarray:
    x = x.astype(np.uint32) ^ np.uint32(seed)
    x = (x ^ (x >> 16)) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * np.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def mix32_jnp(x: jnp.ndarray, seed) -> jnp.ndarray:
    """``seed`` may be a Python int or a (broadcastable) int array — the
    dense fused-ingest kernel passes per-column seed planes."""
    if isinstance(seed, int):
        seed = np.uint32(seed)  # ints can exceed int32; wrap before tracing
    x = x.astype(jnp.uint32) ^ jnp.asarray(seed).astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def bucket_np(x: np.ndarray, seed: int, dim: int) -> np.ndarray:
    return (mix32_np(x, seed) % np.uint32(dim)).astype(np.int32)


def bucket_jnp(x: jnp.ndarray, seed: int, dim: int) -> jnp.ndarray:
    return (mix32_jnp(x, seed) % jnp.uint32(dim)).astype(jnp.int32)


def row_weight_np(rows: np.ndarray, seed: int, mod: int = 251) -> np.ndarray:
    """Small per-tuple weight for orderless join checksums (host side)."""
    acc = np.uint32(seed)
    h = np.full(rows.shape[0], acc, dtype=np.uint32)
    for j in range(rows.shape[1]):
        h = mix32_np(rows[:, j].astype(np.uint32) + h, seed + j + 1)
    return (h % np.uint32(mod)).astype(np.int32) + 1


def row_weight_jnp(rows: jnp.ndarray, seed: int, mod: int = 251) -> jnp.ndarray:
    h = jnp.full(rows.shape[0], jnp.uint32(seed), dtype=jnp.uint32)
    for j in range(rows.shape[1]):
        h = mix32_jnp(rows[:, j].astype(jnp.uint32) + h, seed + j + 1)
    return (h % jnp.uint32(mod)).astype(jnp.int32) + 1
