"""qwen3-moe-30b-a3b [moe]: 48L d=2048 32H (GQA kv=4) d_expert=768 V=151936.

MoE: 128 routed experts, top-8, no shared expert; qk-norm.
[hf:Qwen/Qwen3-30B-A3B; hf]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv=4,
    head_dim=128,
    d_ff=768,
    vocab=151936,
    act="silu",
    norm="rms",
    rope_theta=1_000_000.0,
    qk_norm=True,
    tie_embeddings=False,
    n_experts=128,
    top_k=8,
    n_shared=0,
    d_expert=768,
))
