"""Architecture config schema + registry + input shapes.

Each assigned architecture gets one file in this package defining an
``ArchConfig`` with the exact public-literature dimensions; ``reduced()``
yields the CPU-smoke-test version of the same family (same code path, tiny
dims).  The four input-shape regimes from the brief are defined here as
``SHAPES``; ``supported_shapes(cfg)`` encodes the skip rules documented in
DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    act: str = "silu"
    norm: str = "rms"  # rms | layer | nonparametric
    rope_theta: float = 1e4
    attn_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = True
    # sliding-window pattern (Gemma3): every `global_period`-th layer is
    # global, the rest use `window`
    window: int = 0  # 0 = all layers full attention
    global_period: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0  # shared (always-on) experts
    d_expert: int = 0  # per-expert FFN width
    # SSM / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    d_inner: int = 0
    hybrid_period: int = 0  # Zamba: shared attention block every N layers
    # modality / topology
    frontend: str = ""  # "" | "patch" (VLM) | "frame" (audio)
    causal: bool = True
    has_decoder: bool = True  # encoder-only archs have no decode step
    max_seq: int = 131072

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=4 if self.hybrid_period else 2,
            d_model=64,
            n_heads=4,
            n_kv=2 if self.n_kv < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab=512,
            window=16 if self.window else 0,
            global_period=2 if self.global_period else 0,
            n_experts=8 if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared=1 if self.n_shared else 0,
            d_expert=32 if self.d_expert else 0,
            ssm_state=8 if self.ssm_state else 0,
            ssm_heads=4 if self.ssm_heads else 0,
            d_inner=128 if self.d_inner else 0,
            hybrid_period=2 if self.hybrid_period else 0,
            max_seq=256,
        )

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, f, l = self.d_model, self.d_ff, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * self.hd * (self.n_heads + 2 * self.n_kv) + self.n_heads * self.hd * d
        if self.family == "ssm":  # rwkv6-style block
            blk = 2 * d * self.d_ff + d * self.d_ff + 5 * d * d  # ffn + mixing
        elif self.family == "hybrid":
            di = self.d_inner or 2 * d
            mamba = d * di * 2 + di * d + di * (2 * self.ssm_state)
            blk = mamba + 2 * d * f + d * f  # + shared attn amortized
        elif self.n_experts:
            expert = 3 * d * self.d_expert
            shared = 3 * d * self.d_expert * 4 if self.n_shared else 0
            blk = attn + self.n_experts * expert + shared + d * self.n_experts
        else:
            blk = attn + 3 * d * f
        return emb + l * blk

    def n_active_params(self) -> int:
        """Active (per-token) parameters — differs for MoE."""
        if not self.n_experts:
            return self.n_params()
        d, l = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * self.hd * (self.n_heads + 2 * self.n_kv) + self.n_heads * self.hd * d
        expert = 3 * d * self.d_expert
        active = attn + (self.top_k + 4 * self.n_shared) * expert + d * self.n_experts
        return emb + l * active


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic / mostly-local attention);
# see DESIGN.md §Arch-applicability
_LONG_OK_FAMILIES = {"ssm", "hybrid"}


def supported_shapes(cfg: ArchConfig) -> list[str]:
    out = ["train_4k", "prefill_32k"]
    if cfg.has_decoder:
        out.append("decode_32k")
        if cfg.family in _LONG_OK_FAMILIES or (cfg.window and cfg.global_period):
            out.append("long_500k")
    return out


def skip_reason(cfg: ArchConfig, shape: str) -> str | None:
    if shape in supported_shapes(cfg):
        return None
    if not cfg.has_decoder:
        return "encoder-only: no autoregressive decode step"
    return (
        "pure full-attention arch: 500k-context KV cache exceeds HBM and the "
        "arch defines no sub-quadratic path (DESIGN.md §Arch-applicability)"
    )


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    return _REGISTRY[name]


def all_configs() -> dict[str, ArchConfig]:
    _ensure_loaded()
    return dict(_REGISTRY)


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    from . import (  # noqa: F401
        command_r_plus_104b,
        gemma3_4b,
        granite_3_8b,
        hubert_xlarge,
        internvl2_1b,
        olmo_1b,
        qwen2_moe_a2_7b,
        qwen3_moe_30b_a3b,
        rwkv6_3b,
        zamba2_2_7b,
    )
