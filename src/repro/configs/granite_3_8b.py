"""granite-3-8b [dense]: 40L d=4096 32H (GQA kv=8) ff=12800 V=49155.

[hf:ibm-granite/granite-3.0-2b-base; hf]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=12800,
    vocab=49155,
    act="silu",
    norm="rms",
    rope_theta=10_000_000.0,
    tie_embeddings=True,
))
