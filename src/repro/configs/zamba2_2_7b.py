"""zamba2-2.7b [hybrid]: 54L d=2560 Mamba2 backbone + shared attention
blocks (32H kv=32) every 6 layers, ff=10240, V=32000, ssm_state=64.

[arXiv:2411.15242; hf]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv=32,
    head_dim=80,
    d_ff=10240,
    vocab=32000,
    act="gelu",
    norm="rms",
    tie_embeddings=True,
    ssm_state=64,
    ssm_heads=80,     # d_inner 5120, head dim 64
    d_inner=5120,
    hybrid_period=6,
))
