"""hubert-xlarge [audio]: 48L d=1280 16H (kv=16) ff=5120 V=504.

Encoder-only (same arch as wav2vec2); conv frame frontend is a STUB —
input_specs() provides precomputed frame embeddings.
[arXiv:2106.07447; unverified]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv=16,
    d_ff=5120,
    vocab=504,
    act="gelu",
    norm="layer",
    attn_bias=True,
    tie_embeddings=False,
    frontend="frame",
    causal=False,
    has_decoder=False,
))
