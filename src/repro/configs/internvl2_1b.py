"""internvl2-1b [vlm]: 24L d=896 14H (GQA kv=2) ff=4864 V=151655.

InternViT frontend (STUB: precomputed patch embeddings) + InternLM2/Qwen2
0.5B language backbone. [arXiv:2404.16821; hf]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv=2,
    d_ff=4864,
    vocab=151655,
    act="silu",
    norm="rms",
    rope_theta=1_000_000.0,
    attn_bias=True,
    tie_embeddings=True,
    frontend="patch",
))
