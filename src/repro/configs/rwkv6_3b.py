"""rwkv6-3b [ssm]: 32L d=2560 (attention-free) ff=8960 V=65536.

RWKV-6 "Finch" — data-dependent decay. [arXiv:2404.05892; hf]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,       # wkv head size 64
    n_kv=40,
    head_dim=64,
    d_ff=8960,
    vocab=65536,
    act="relu",       # rwkv channel-mix uses relu^2
    norm="layer",
    tie_embeddings=False,
))
