"""qwen2-moe-a2.7b [moe]: 24L d=2048 16H (kv=16) d_expert=1408 V=151936.

MoE: 60 routed experts top-4 + 4-way shared expert (shared width 5632 =
4 x 1408). [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=5632,        # shared-expert width
    vocab=151936,
    act="silu",
    norm="rms",
    rope_theta=1_000_000.0,
    attn_bias=True,
    tie_embeddings=False,
    n_experts=60,
    top_k=4,
    n_shared=4,
    d_expert=1408,
))
