"""gemma3-4b [dense]: 34L d=2560 8H (GQA kv=4) ff=10240 V=262144.

5:1 local:global sliding-window pattern, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv=4,
    d_ff=10240,
    vocab=262144,
    head_dim=256,
    act="gelu_tanh",
    norm="rms",
    rope_theta=1_000_000.0,
    qk_norm=True,
    tie_embeddings=True,
    window=1024,
    global_period=6,  # every 6th layer global -> 5:1 local:global
    max_seq=131072,
))
