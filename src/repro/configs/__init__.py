"""Assigned-architecture configs (exact public dims) + shape regimes."""
from .base import (
    ArchConfig,
    SHAPES,
    ShapeSpec,
    all_configs,
    get_config,
    skip_reason,
    supported_shapes,
)

__all__ = [
    "ArchConfig",
    "SHAPES",
    "ShapeSpec",
    "all_configs",
    "get_config",
    "skip_reason",
    "supported_shapes",
]
