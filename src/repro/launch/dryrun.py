import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# the dry-run analyses compiled artifacts on fake host devices; never let
# jax grab a real accelerator (libtpu init hangs on non-TPU hosts)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

"""Multi-pod dry-run (brief deliverable (e)): lower + compile every
(architecture x input shape) on the production meshes and extract the
roofline inputs.

For each cell this script:
  1. builds the (16,16) single-pod or (2,16,16) multi-pod mesh,
  2. lowers the right step (train_step / prefill_step / serve_step) with
     full in/out shardings from ``launch.sharding``,
  3. compiles, records ``memory_analysis()`` + ``cost_analysis()``,
  4. parses the compiled HLO for collective ops and sums their bytes,
  5. writes one JSON artifact under benchmarks/artifacts/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--arch ...]
"""
import argparse
import json
import math
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, all_configs, get_config, skip_reason
from repro.launch import sharding as rules
from repro.launch.mesh import data_axes, make_production_mesh
from repro.models import build_model
from repro.models.layers import set_activation_sharding
from repro.train import OptConfig, make_train_step
from repro.train.optimizer import init_opt_state

ARTIFACT_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(__file__)))),
    "benchmarks", "artifacts", "dryrun",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of an HLO shape string, incl. tuples '(bf16[2,3], f32[4])'."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the HLO."""
    out = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w\.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", line)
        if not m:
            continue
        op = m.group(2)
        if op.endswith("-start"):
            op = op[:-6]
        if op in out:
            out[op]["count"] += 1
            out[op]["bytes"] += _shape_bytes(m.group(1))
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items() if isinstance(v, dict))
    return out


def input_specs(cfg, spec, n_patch: int = 256):
    """ShapeDtypeStruct stand-ins for the model inputs of one shape cell."""
    b, s = spec.global_batch, spec.seq_len
    sds = jax.ShapeDtypeStruct
    if spec.kind in ("train", "prefill"):
        if cfg.family == "audio":
            return {
                "prefix_embeds": sds((b, s, cfg.d_model), jnp.bfloat16),
                "labels": sds((b, s), jnp.int32),
            }
        batch = {"tokens": sds((b, s - (n_patch if cfg.family == "vlm" else 0)), jnp.int32)}
        if cfg.family == "vlm":
            batch["prefix_embeds"] = sds((b, n_patch, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one new token against a seq_len-deep cache
    return {"tokens": sds((b, 1), jnp.int32)}


def _moe_kwargs(cfg, spec, extra_slots, model_size=16):
    if cfg.family != "moe":
        return {}
    pad = -(-cfg.n_experts // model_size) * model_size  # round up to tile TP
    cf_train = float(os.environ.get("REPRO_CAPACITY_FACTOR", "1.25"))
    return {
        "extra_slots": extra_slots,
        "capacity_factor": cf_train if spec.kind == "train" else 2.0,
        "expert_pad": pad if pad != cfg.n_experts else 0,
    }


def build_cell(arch: str, shape: str, multi_pod: bool, extra_slots: int = 16):
    """Returns (step_fn, example_args, in_shardings, out_shardings, donate)."""
    cfg = get_config(arch)
    spec = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = data_axes(multi_pod)
    model_size = mesh.shape["model"]
    data_size = mesh.shape["data"] * (mesh.shape.get("pod", 1))
    model = build_model(cfg)

    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    fsdp = int(os.environ.get("REPRO_FSDP", "1"))
    p_spec = rules.param_specs(
        params_shape, model_size, data_size=mesh.shape["data"] if fsdp else 1
    )
    p_shard = rules.named(mesh, p_spec)

    batch_shape = input_specs(cfg, spec)
    b_spec = rules.batch_specs(batch_shape, dp)
    b_shard = rules.named(mesh, b_spec)

    axis_sizes = dict(mesh.shape)
    set_activation_sharding(P(dp, "model", None), axis_sizes)

    dp_total = int(np.prod([mesh.shape[a] for a in dp]))

    def logits_sharding(batch_dim: int) -> NamedSharding:
        b_ax = dp if batch_dim % dp_total == 0 and batch_dim > 1 else None
        v_ax = "model" if cfg.vocab % model_size == 0 else None
        return NamedSharding(mesh, P(b_ax, v_ax))

    mkw = _moe_kwargs(cfg, spec, extra_slots, model_size)

    if spec.kind == "train":
        opt_shape = jax.eval_shape(lambda p: init_opt_state(p), params_shape)
        o_spec = rules.opt_specs(p_spec, params_shape, data_size)
        # moment specs computed for m; reuse for v; step scalar replicated
        o_spec = {"m": o_spec["m"], "v": o_spec["v"], "step": P()}
        o_shard = rules.named(mesh, o_spec)
        opt_cfg = OptConfig()
        step = make_train_step(model, opt_cfg, loss_kwargs=mkw)
        args = (params_shape, opt_shape, batch_shape)
        in_sh = (p_shard, o_shard, b_shard)
        out_sh = (p_shard, o_shard, {"grad_norm": NamedSharding(mesh, P()),
                                     "lr": NamedSharding(mesh, P()),
                                     "loss": NamedSharding(mesh, P())})
        return mesh, step, args, in_sh, out_sh, (0, 1)

    if spec.kind == "prefill":
        def prefill_step(params, batch):
            out = model.forward_hidden(params, batch, dtype=jnp.bfloat16, remat=False)
            h = out[0] if isinstance(out, tuple) else out
            if cfg.family == "ssm":
                table = params["lm_head"]["w"].T
            else:
                from repro.models.transformer import logits_table

                table = logits_table(cfg, params)
            return (h[:, -1, :] @ table.T.astype(h.dtype)).astype(jnp.float32)

        args = (params_shape, batch_shape)
        in_sh = (p_shard, b_shard)
        out_sh = logits_sharding(spec.global_batch)
        return mesh, prefill_step, args, in_sh, out_sh, ()

    # decode
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(spec.global_batch, spec.seq_len)
    )
    c_spec = rules.cache_specs(cache_shape, dp, model_size)
    c_shard = rules.named(mesh, c_spec)
    pos_shape = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(params, cache, batch, pos):
        return model.decode_step(params, cache, batch["tokens"], pos, **mkw)

    args = (params_shape, cache_shape, batch_shape, pos_shape)
    in_sh = (p_shard, c_shard, b_shard, NamedSharding(mesh, P()))
    out_sh = (logits_sharding(spec.global_batch), c_shard)
    return mesh, serve_step, args, in_sh, out_sh, (1,)


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str, extra_slots: int = 16) -> dict:
    cfg = get_config(arch)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record = {"arch": arch, "shape": shape, "mesh": mesh_name, "status": "ok"}
    reason = skip_reason(cfg, shape)
    if reason:
        record.update(status="skipped", reason=reason)
        _write(out_dir, record)
        return record
    t0 = time.time()
    try:
        mesh, step, args, in_sh, out_sh, donate = build_cell(
            arch, shape, multi_pod, extra_slots
        )
        with mesh:
            jitted = jax.jit(
                step, in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=donate,
            )
            lowered = jitted.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):  # older jax wraps in a list
                cost = cost[0] if cost else None
            text = compiled.as_text()
        from repro.launch.hlo_analysis import analyze

        deep = analyze(text)  # trip-count-aware per-device costs
        record.update(
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            hlo_bytes=len(text),
            # raw XLA numbers (loop bodies counted once — kept for reference)
            xla_flops=float(cost.get("flops", -1)) if cost else -1,
            xla_bytes_accessed=float(cost.get("bytes accessed", -1)) if cost else -1,
            # trip-count-aware per-device analysis (the roofline inputs)
            flops=deep["flops"],
            hbm_bytes=deep["hbm_bytes"],
            collectives=deep["collectives"],
            collective_payload_bytes=deep["collective_payload_bytes"],
            collective_wire_bytes=deep["collective_wire_bytes"],
            memory=_memory_dict(mem),
            n_devices=int(np.prod(list(mesh.shape.values()))),
        )
    except Exception as e:  # record the failure, don't kill the sweep
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      trace=traceback.format_exc()[-2000:])
    finally:
        set_activation_sharding(None)
    _write(out_dir, record)
    return record


def _memory_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for attr in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes", "host_argument_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out:
        out["repr"] = str(mem)[:2000]
    return out


def _write(out_dir: str, record: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{record['mesh']}__{record['arch']}__{record['shape']}.json"
    )
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    status = record["status"]
    extra = ""
    if status == "ok":
        extra = (
            f" flops/dev={record['flops']:.3e}"
            f" hbm/dev={record['hbm_bytes']:.3e}"
            f" wire/dev={record['collective_wire_bytes']:.3e}"
            f" compile={record['compile_s']}s"
        )
    elif status == "error":
        extra = " " + record["error"][:200]
    print(f"[dryrun] {record['mesh']} {record['arch']} {record['shape']}: {status}{extra}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--extra-slots", type=int, default=16)
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()

    archs = args.arch or (sorted(all_configs()) if args.all else None)
    shapes = args.shape or (list(SHAPES) if args.all else None)
    if not archs or not shapes:
        ap.error("pass --arch/--shape or --all")
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    failures = 0
    for mp in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, mp, args.out, args.extra_slots)
                failures += rec["status"] == "error"
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
