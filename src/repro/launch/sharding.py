"""Sharding rules: params / optimizer state / batches / caches -> PartitionSpec.

Policy (DESIGN.md §5): TP over "model" (attention heads, MLP columns, expert
dim, vocab), DP over ("pod","data"), ZeRO-1 for optimizer moments (large
replicated leaves get their biggest divisible dim sharded over "data").
Rules match on parameter-path suffixes with a size-aware generic fallback,
so every architecture family (incl. RWKV/Mamba stacks) gets a complete
spec tree without per-arch boilerplate.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# path-suffix -> which logical dim (counted from the END, ignoring the
# stacked layer dim) to shard over "model"
_COL = -1  # output/column-parallel (shard last dim)
_ROW = -2  # input/row-parallel (shard second-to-last dim)
_SUFFIX_RULES: list[tuple[str, int]] = [
    ("embed/table", 0),          # vocab-sharded embedding
    ("lm_head/w", _COL),         # [d, V] -> shard vocab
    ("attn/wq/..pad", _COL),
    ("wq", _COL), ("wk", _COL), ("wv", _COL), ("wo", _ROW),
    ("w_gate", _COL), ("w_up", _COL), ("w_down", _ROW),
    ("Wr", _COL), ("Wk", _COL), ("Wv", _ROW), ("Wg", _COL), ("Wo", _ROW),
    ("in_proj", _COL), ("out_proj", _ROW),
]
_EXPERT_RULES = ("experts/w_gate", "experts/w_up", "experts/w_down")


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


# leaves >= this many elements also get FSDP-sharded over "data" (a 104B
# model sharded only 16-way TP is 26 GB fp32 per device — over HBM; with the
# extra data-axis dim it is 1.6 GB).  XLA inserts the per-layer all-gathers
# (FSDP); scan bodies re-gather one layer at a time.
FSDP_THRESHOLD = 1 << 24


def _add_fsdp(dims: list, shape: tuple[int, ...], data_size: int, base: int) -> None:
    if data_size <= 1 or int(np.prod(shape)) < FSDP_THRESHOLD:
        return
    order = sorted(range(base, len(shape)), key=lambda i: -shape[i])
    for i in order:
        if dims[i] is None and shape[i] % data_size == 0:
            dims[i] = "data"
            return


def _spec_for(
    path: str, shape: tuple[int, ...], model_size: int, stacked: bool,
    data_size: int = 1,
) -> P:
    ndim = len(shape)
    dims: list[Any] = [None] * ndim
    base = 1 if stacked else 0  # skip the scanned layer axis

    for suffix in _EXPERT_RULES:
        if path.endswith(suffix):
            # [L, E, d, f] -> expert parallelism over "model"
            if shape[base] % model_size == 0:
                dims[base] = "model"
                _add_fsdp(dims, shape, data_size, base)
                return P(*dims)

    for suffix, rule in _SUFFIX_RULES:
        if path.endswith(suffix):
            idx = rule if rule < 0 else base + rule
            if ndim >= (2 if not stacked else 3) or (rule == 0 and ndim >= 2):
                if shape[idx] % model_size == 0:
                    dims[idx] = "model"
                    _add_fsdp(dims, shape, data_size, base)
                    return P(*dims)
            break

    # generic fallback: big leaves shard their largest divisible dim
    if np.prod(shape) >= 1 << 22:
        order = sorted(range(base, ndim), key=lambda i: -shape[i])
        for i in order:
            if shape[i] % model_size == 0:
                dims[i] = "model"
                _add_fsdp(dims, shape, data_size, base)
                return P(*dims)
    dims = [None] * ndim
    _add_fsdp(dims, shape, data_size, base)
    if all(d is None for d in dims):
        return P()
    return P(*dims)


def param_specs(params_shape: Any, model_size: int, data_size: int = 1) -> Any:
    """PartitionSpec pytree for a params (or shape-struct) pytree.
    ``data_size`` > 1 enables FSDP sharding of large leaves over "data"."""

    def spec(path, leaf):
        p = _path_str(path)
        stacked = p.startswith("blocks")
        return _spec_for(p, tuple(leaf.shape), model_size, stacked, data_size)

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def opt_specs(params_spec: Any, params_shape: Any, data_size: int, zero1: bool = True) -> dict:
    """Optimizer-state specs: moments follow params; ZeRO-1 additionally
    shards big *replicated* moments over "data"."""

    def mom(spec: P, leaf) -> P:
        if not zero1:
            return spec
        if any(s is not None for s in spec) or np.prod(leaf.shape) < (1 << 20):
            return spec
        dims = [None] * len(leaf.shape)
        for i in sorted(range(len(leaf.shape)), key=lambda i: -leaf.shape[i]):
            if leaf.shape[i] % data_size == 0:
                dims[i] = "data"
                return P(*dims)
        return spec

    m = jax.tree.map(mom, params_spec, params_shape)
    return {"m": m, "v": jax.tree.map(lambda s: s, m), "step": P()}


def batch_specs(batch_shape: dict, dp: tuple[str, ...]) -> dict:
    """Batch dim over the data axes; everything else replicated."""
    def spec(leaf):
        dims = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1 and leaf.shape[0] > 1:
            dims[0] = dp
        return P(*dims)

    return jax.tree.map(spec, batch_shape)


def cache_specs(cache_shape: Any, dp: tuple[str, ...], model_size: int) -> Any:
    """Decode caches: batch dim over data axes; within each leaf, shard heads
    (or head_dim / long sequence) over "model"/"data" where divisible.

    Layouts: KV [L, B, Hkv, S, hd]; rwkv wkv [L, B, H, hd, hd];
    mamba ssm [L, B, H, p, s]; conv [L, B, K, di]; x_prev [L, B, d]."""

    def spec(leaf) -> P:
        shape = tuple(leaf.shape)
        dims: list[Any] = [None] * len(shape)
        if len(shape) >= 2:
            if shape[1] > 1:
                dims[1] = dp  # batch
        if len(shape) == 5:
            l, b, h, s_or_p, last = shape
            if h % model_size == 0:
                dims[2] = "model"
            elif last % model_size == 0:
                dims[4] = "model"
            if b == 1 and len(dp) == 1 and s_or_p % (16) == 0 and s_or_p >= 4096:
                dims[3] = dp  # long-context: shard the KV sequence over data
        elif len(shape) == 4:
            if shape[-1] % model_size == 0:
                dims[-1] = "model"
        elif len(shape) == 3:
            if shape[-1] % model_size == 0:
                dims[-1] = "model"
        return P(*dims)

    return jax.tree.map(spec, cache_shape)


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
