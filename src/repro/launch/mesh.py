"""Production meshes (DESIGN.md §5).

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device state.  The single-pod mesh is a
(data=16, model=16) grid of one v5e pod (256 chips); multi-pod adds a
leading "pod" axis (2 pods = 512 chips) used purely for data parallelism —
only the gradient all-reduce crosses the pod boundary.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if hasattr(jax.sharding, "AxisType"):  # jax >= 0.5
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def data_axes(multi_pod: bool) -> tuple[str, ...]:
    """Axes that carry batch/data parallelism."""
    return ("pod", "data") if multi_pod else ("data",)


def make_host_mesh(axis_name: str = "data"):
    """All local devices on one axis (tests / examples on CPU)."""
    import numpy as np

    devs = np.array(jax.devices())
    return jax.sharding.Mesh(devs, (axis_name,))
