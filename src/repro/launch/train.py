"""Production training launcher.

Wires together: config -> model -> sharding rules -> jitted train step ->
data pipeline -> checkpoint manager -> elastic/preemption handling.  On a
real pod this runs under `--mesh prod`; on a dev box `--mesh host` uses
whatever local devices exist (the same code path, smaller grid).

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.launch import sharding as rules
from repro.launch.mesh import data_axes, make_host_mesh, make_production_mesh
from repro.models import build_model
from repro.models.layers import set_activation_sharding
from repro.train import (
    AsyncCheckpointer,
    OptConfig,
    PreemptionGuard,
    latest_step,
    load_checkpoint,
    make_train_step,
    restore_tree,
)
from repro.train.optimizer import init_opt_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", choices=("host", "prod", "prod-multipod"), default="host")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--extra-slots", type=int, default=8, help="MoE SharesSkew replicas")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)

    if args.mesh == "host":
        mesh = make_host_mesh("data")
        dp: tuple[str, ...] = ("data",)
        model_size = 1
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "prod-multipod")
        dp = data_axes(args.mesh == "prod-multipod")
        model_size = mesh.shape["model"]

    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    p_spec = rules.param_specs(params_shape, model_size)
    p_shard = rules.named(mesh, p_spec)
    if model_size > 1:
        set_activation_sharding(P(dp, "model", None), dict(mesh.shape))

    opt_cfg = OptConfig(total_steps=args.steps, warmup_steps=max(5, args.steps // 20))
    loss_kwargs = {"extra_slots": args.extra_slots} if cfg.family == "moe" else {}
    step_fn = jax.jit(
        make_train_step(model, opt_cfg, loss_kwargs), donate_argnums=(0, 1)
    )

    with mesh:
        params = jax.jit(model.init_params, out_shardings=p_shard)(
            jax.random.PRNGKey(0)
        )
        opt_state = init_opt_state(params)

        pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq, seed=0)
        start = 0
        ckpt = AsyncCheckpointer(args.ckpt_dir, keep=3) if args.ckpt_dir else None
        if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            start, flat = load_checkpoint(args.ckpt_dir)
            tree = restore_tree(
                {"params": params, "opt": opt_state},
                flat,
                shardings={"params": p_shard, "opt": jax.tree.map(lambda _: None, opt_state) and None},
            )
            params, opt_state = tree["params"], tree["opt"]
            pipe.step = start
            print(f"resumed from step {start} (resharded onto {mesh.shape})")

        with PreemptionGuard() as guard:
            t0 = time.time()
            for step in range(start, args.steps):
                batch = {"tokens": jnp.asarray(pipe.next_batch())}
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                if step % 10 == 0 or step == args.steps - 1:
                    tput = (step - start + 1) * args.batch * args.seq / (
                        time.time() - t0
                    )
                    print(
                        f"step {step:5d} loss={float(metrics['loss']):.4f} "
                        f"tok/s={tput:.0f}"
                    )
                stop = guard.should_stop
                if ckpt and (stop or (step + 1) % args.ckpt_every == 0):
                    ckpt.save(step + 1, {"params": params, "opt": opt_state})
                if stop:
                    print("preempted -> checkpointed")
                    break
        if ckpt:
            ckpt.wait()
    set_activation_sharding(None)


if __name__ == "__main__":
    main()
