"""Trip-count-aware HLO cost analysis for the roofline (§Roofline).

``compiled.cost_analysis()`` counts while-loop bodies ONCE regardless of
trip count — useless for scan-over-layers models.  This module parses the
compiled SPMD HLO text directly and walks the call graph:

  * ``while`` ops carry ``backend_config={"known_trip_count":{"n":...}}``
    (XLA records it for lax.scan) — bodies are multiplied by it;
  * ``fusion``/``call`` recurse with multiplier 1;
  * dot FLOPs are exact: 2 * prod(result dims) * prod(contracted lhs dims),
    with operand shapes resolved through a module-wide symbol table;
  * elementwise / reduce ops count one FLOP per output (resp. input) item;
  * HBM-bytes are accumulated at materialization boundaries (fusions, dots,
    copies, slices, collectives) — fusion *internals* are VMEM-resident and
    contribute FLOPs only;
  * collectives record payload bytes and estimated per-device *wire* bytes
    (ring-algorithm factors with the replica-group size parsed per op).

All numbers are PER DEVICE (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\(")
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w\.\-]+)")
_COND_BODY = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_COMPACT = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([\d,]+)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "exponential", "tanh", "negate", "select", "compare", "and", "or",
    "xor", "not", "power", "sqrt", "rsqrt", "log", "floor", "ceil", "sign",
    "cosine", "sine", "clamp", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "atan2", "expm1",
    "log-plus-one", "round-nearest-afz", "is-finite",
}
# Ops that write HBM on TPU.  Standalone convert / broadcast / transpose /
# iota / pad are layout-level ops the TPU compiler fuses into consumers, so
# they carry no traffic here (their reads are charged to the consumer).
_MATERIALIZING = {
    "fusion", "dot", "copy", "reduce", "dynamic-update-slice", "slice",
    "concatenate", "gather", "scatter", "reduce-window", "sort",
    "convolution", "all-reduce", "all-gather", "reduce-scatter",
    "all-to-all", "collective-permute", "copy-start", "copy-done",
    "dynamic-slice",
}
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# ring-algorithm wire-bytes factor given group size n, relative to payload
_WIRE_FACTOR = {
    "all-reduce": lambda n: 2.0 * (n - 1) / max(n, 1),
    "all-gather": lambda n: (n - 1) / max(n, 1),
    "reduce-scatter": lambda n: float(n - 1),  # payload = scattered result
    "all-to-all": lambda n: (n - 1) / max(n, 1),
    "collective-permute": lambda n: 1.0,
}


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems = 0
    nbytes = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dtype]
    return elems, nbytes


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_payload: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_wire: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_count: dict = dataclasses.field(default_factory=lambda: defaultdict(float))

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll_payload.items():
            self.coll_payload[k] += v * mult
        for k, v in other.coll_wire.items():
            self.coll_wire[k] += v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] += v * mult


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[str]] = {}
        self.shapes: dict[str, str] = {}
        self.entry: str | None = None
        self._parse(text)
        self._memo: dict[str, Costs] = {}

    def _parse(self, text: str) -> None:
        cur: list[str] | None = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                m = _COMP_HEADER.match(line.strip())
                if m and line.rstrip().endswith("{"):
                    name = m.group(2)
                    self.computations[name] = cur = []
                    if m.group(1):
                        self.entry = name
                    # parameters: "pname: shape, ..."
                    for pm in re.finditer(r"([\w\.\-]+):\s*([\w\[\],\{\}]+)", m.group(3)):
                        self.shapes[pm.group(1)] = pm.group(2)
                continue
            if line.strip() == "}":
                cur = None
                continue
            cur.append(line)
            im = _INSTR.match(line)
            if im:
                self.shapes[im.group(1)] = im.group(2)

    # ------------------------------------------------------------- per-op
    def _instr_costs(self, line: str, costs: Costs) -> list[tuple[str, float]]:
        """Accumulate this instruction into ``costs``; return callee list
        [(computation, multiplier)]."""
        im = _INSTR.match(line)
        if not im:
            return []
        _, shape_str, op = im.groups()
        elems, nbytes = _shape_elems_bytes(shape_str)

        callees: list[tuple[str, float]] = []
        if op == "while":
            tm = _TRIP.search(line)
            trip = float(tm.group(1)) if tm else 1.0
            cb = _COND_BODY.search(line)
            if cb:
                callees.append((cb.group(1), trip))
                callees.append((cb.group(2), trip))
            return callees
        if op == "fusion":
            cm = _CALLS.search(line)
            if cm:
                callees.append((cm.group(1), 1.0))
            costs.bytes += nbytes + self._operand_bytes(line)
            return callees
        if op in ("call", "custom-call"):
            tm = _TO_APPLY.search(line)
            if tm:
                callees.append((tm.group(1), 1.0))
            return callees
        if op == "conditional":
            for bm in re.finditer(r"(?:true_computation|false_computation|branch_computations=\{)%?([\w\.\-]+)", line):
                callees.append((bm.group(1), 1.0))
            return callees

        if op == "dot":
            dims = _shape_dims(shape_str)
            out_elems = 1
            for d in dims:
                out_elems *= d
            lhs = _OPERANDS.findall(line[line.index("("):])
            contract = 1
            if lhs:
                lhs_shape = self.shapes.get(lhs[0], "")
                lhs_dims = _shape_dims(lhs_shape)
                cm = _LHS_CONTRACT.search(line)
                if cm and cm.group(1):
                    for ci in cm.group(1).split(","):
                        ci = int(ci)
                        if ci < len(lhs_dims):
                            contract *= lhs_dims[ci]
            costs.flops += 2.0 * out_elems * contract
            costs.bytes += nbytes + self._operand_bytes(line)
            return []

        if op == "convolution":
            # approximation: 2 * out_elems * kernel_elems (kernel = operand 1)
            ops = _OPERANDS.findall(line[line.index("("):])
            kernel_elems = 1
            if len(ops) > 1:
                ke, _ = _shape_elems_bytes(self.shapes.get(ops[1], ""))
                kernel_elems = max(ke, 1)
            costs.flops += 2.0 * elems * kernel_elems
            costs.bytes += nbytes + self._operand_bytes(line)
            return []

        if op in _COLLECTIVES:
            n = self._group_size(line)
            payload = nbytes
            costs.coll_payload[op] += payload
            costs.coll_wire[op] += payload * _WIRE_FACTOR[op](n)
            costs.coll_count[op] += 1
            costs.bytes += nbytes + self._operand_bytes(line)
            return []

        if op in _ELEMENTWISE:
            costs.flops += elems
            return []
        if op in ("reduce", "reduce-window"):
            in_elems = 0
            for o in _OPERANDS.findall(line[line.index("("):])[:1]:
                e, _ = _shape_elems_bytes(self.shapes.get(o, ""))
                in_elems += e
            costs.flops += max(in_elems, elems)
            costs.bytes += nbytes + self._operand_bytes(line)
            return []
        if op in ("dynamic-slice", "slice", "gather"):
            # only the sliced region moves, not the source buffer
            costs.bytes += 2 * nbytes
            return []
        if op == "dynamic-update-slice":
            # in-place update: traffic = read+write of the update region
            ops = _OPERANDS.findall(line[line.index("("):].split("), ")[0])
            upd = self.shapes.get(ops[1], "") if len(ops) > 1 else shape_str
            _, ub = _shape_elems_bytes(upd)
            costs.bytes += 2 * ub
            return []
        if op == "scatter":
            costs.bytes += 2 * nbytes
            return []
        if op in _MATERIALIZING:
            costs.bytes += nbytes + self._operand_bytes(line)
        return []

    def _operand_bytes(self, line: str) -> int:
        try:
            args = line[line.index("("):]
        except ValueError:
            return 0
        # cut off attribute section to avoid counting e.g. to_apply refs
        args = args.split("), ")[0]
        total = 0
        for name in _OPERANDS.findall(args):
            _, b = _shape_elems_bytes(self.shapes.get(name, ""))
            total += b
        return total

    def _group_size(self, line: str) -> int:
        m = _GROUPS_COMPACT.search(line)
        if m:
            return int(m.group(2))
        m = _GROUPS_LIST.search(line)
        if m:
            return len(m.group(1).split(","))
        return 1

    # --------------------------------------------------------- call graph
    def computation_costs(self, name: str) -> Costs:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Costs()  # cycle guard
        total = Costs()
        for line in self.computations.get(name, ()):
            callees = self._instr_costs(line, total)
            for callee, mult in callees:
                total.add(self.computation_costs(callee), mult)
        self._memo[name] = total
        return total

    def entry_costs(self) -> Costs:
        if self.entry is None:
            raise ValueError("no ENTRY computation found")
        return self.computation_costs(self.entry)


def analyze(hlo_text: str) -> dict:
    """Full per-device cost dict for a compiled SPMD module."""
    mod = HloModule(hlo_text)
    c = mod.entry_costs()
    return {
        "flops": c.flops,
        "hbm_bytes": c.bytes,
        "collectives": {
            op: {
                "count": c.coll_count.get(op, 0.0),
                "payload_bytes": c.coll_payload.get(op, 0.0),
                "wire_bytes": c.coll_wire.get(op, 0.0),
            }
            for op in _COLLECTIVES
            if c.coll_count.get(op)
        },
        "collective_payload_bytes": sum(c.coll_payload.values()),
        "collective_wire_bytes": sum(c.coll_wire.values()),
    }
