"""Launch layer: production meshes, sharding rules, dry-run, train driver."""
from .mesh import data_axes, make_host_mesh, make_production_mesh

__all__ = ["data_axes", "make_host_mesh", "make_production_mesh"]
